//! A minimal line-oriented Rust lexer for the lint pass (offline
//! stand-in for `syn` — the repo's no-dependency discipline applies to
//! its own tooling too).
//!
//! The lexer does one job: split each source line into its *code* text
//! and its *comment* text, with string/char-literal contents blanked
//! out of the code, so the line-oriented rules in
//! [`super::rules`] can pattern-match code without tripping over
//! `"panic! in a string"` or `// unwrap() in a comment`. It also marks
//! lines inside `#[cfg(test)]`-gated regions (including compound forms
//! like `#[cfg(all(test, …))]`), which most rules skip.
//!
//! Handled: `//` line comments, nested `/* */` block comments, string
//! literals with escapes, raw strings `r#"…"#` (any hash count), byte
//! strings/chars, char literals vs. lifetimes. Not handled (and not
//! needed here): attributes spanning lines, macros that generate
//! `unsafe`/collection code.

/// One source line, split into code and comment channels.
#[derive(Clone, Debug, Default)]
pub struct Line {
    /// Code text with string/char-literal contents removed (the
    /// delimiting quotes remain, so `.expect("msg")` reads
    /// `.expect("")`).
    pub code: String,
    /// Comment text (both `//` and `/* */` bodies) on this line.
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)]`-gated item.
    pub in_test: bool,
}

enum State {
    Normal,
    /// Inside `/* */`, tracking nesting depth.
    Block(u32),
    /// Inside a `"…"` string literal.
    Str,
    /// Inside a raw string, closed by `"` followed by this many `#`s.
    RawStr(usize),
}

/// Lex `text` into per-line code/comment channels and mark test
/// regions.
pub fn lex(text: &str) -> Vec<Line> {
    let chars: Vec<char> = text.chars().collect();
    let mut lines: Vec<Line> = Vec::new();
    let mut cur = Line::default();
    let mut state = State::Normal;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                let next = chars.get(i + 1).copied();
                let prev_ident = i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');
                match c {
                    '/' if next == Some('/') => {
                        // Line comment: the rest of the line.
                        while i < chars.len() && chars[i] != '\n' {
                            cur.comment.push(chars[i]);
                            i += 1;
                        }
                    }
                    '/' if next == Some('*') => {
                        state = State::Block(1);
                        i += 2;
                    }
                    '"' => {
                        cur.code.push('"');
                        state = State::Str;
                        i += 1;
                    }
                    'r' | 'b' if !prev_ident && raw_open(&chars, i).is_some() => {
                        let (hashes, body_at) = raw_open(&chars, i).unwrap_or((0, i + 1));
                        cur.code.push_str("r\"");
                        state = State::RawStr(hashes);
                        i = body_at;
                    }
                    'b' if !prev_ident && next == Some('"') => {
                        cur.code.push_str("b\"");
                        state = State::Str;
                        i += 2;
                    }
                    'b' if !prev_ident && next == Some('\'') => {
                        // Byte char literal: delegate to the char arm.
                        cur.code.push('b');
                        i += 1;
                    }
                    '\'' => {
                        if next == Some('\\') {
                            // Escaped char literal: skip the escape
                            // lead-in, then scan to the closing quote
                            // (handles '\'' and '\u{…}').
                            cur.code.push_str("''");
                            i += 3;
                            while i < chars.len() && chars[i] != '\'' {
                                i += 1;
                            }
                            i += 1;
                        } else if chars.get(i + 2).copied() == Some('\'') {
                            // Plain char literal 'x'.
                            cur.code.push_str("''");
                            i += 3;
                        } else {
                            // Lifetime or loop label.
                            cur.code.push('\'');
                            i += 1;
                        }
                    }
                    _ => {
                        cur.code.push(c);
                        i += 1;
                    }
                }
            }
            State::Block(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = State::Block(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth > 1 { State::Block(depth - 1) } else { State::Normal };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    state = State::Normal;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && chars[i + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes {
                    cur.code.push('"');
                    state = State::Normal;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    mark_test_regions(&mut lines);
    lines
}

/// Detect a raw-string opener at `i` (`r"`, `r#"`, `br##"`, …).
/// Returns (hash count, index of the first body char).
fn raw_open(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i + 1;
    if chars[i] == 'b' {
        if chars.get(j).copied() != Some('r') {
            return None;
        }
        j += 1;
    }
    let mut hashes = 0;
    while chars.get(j).copied() == Some('#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j).copied() == Some('"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Mark every line inside a `#[cfg(test)]`-gated item (the attribute
/// line, any lines up to its opening brace, and the braced body).
/// Compound gates like `#[cfg(all(test, feature = "x"))]` count too —
/// the `test` predicate is what makes the code unreachable in library
/// builds.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut region_close: Option<i64> = None;
    for line in lines.iter_mut() {
        if region_close.is_some() || pending {
            line.in_test = true;
        }
        if region_close.is_none() && !pending && is_test_attr(&line.code) {
            pending = true;
            line.in_test = true;
        }
        for ch in line.code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending {
                        region_close = Some(depth);
                        pending = false;
                    }
                }
                '}' => {
                    if region_close == Some(depth) {
                        region_close = None;
                    }
                    depth -= 1;
                }
                _ => {}
            }
        }
    }
}

fn is_test_attr(code: &str) -> bool {
    code.contains("#[cfg(") && has_word(code, "test")
}

/// True when `word` occurs in `s` with non-identifier characters (or
/// the text boundary) on both sides.
pub fn has_word(s: &str, word: &str) -> bool {
    let bytes = s.as_bytes();
    let mut from = 0;
    while let Some(pos) = s[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let ok_left = start == 0 || !is_ident_byte(bytes[start - 1]);
        let ok_right = end == bytes.len() || !is_ident_byte(bytes[end]);
        if ok_left && ok_right {
            return true;
        }
        from = start + 1;
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_code_and_comments() {
        let lines = lex("let x = 1; // unwrap() here is a comment\n");
        assert_eq!(lines.len(), 1);
        assert!(lines[0].code.contains("let x = 1;"));
        assert!(!lines[0].code.contains("unwrap"));
        assert!(lines[0].comment.contains("unwrap()"));
    }

    #[test]
    fn blanks_string_contents_keeps_quotes() {
        let lines = lex("call(\"panic! inside\"); other();\n");
        assert!(!lines[0].code.contains("panic!"));
        assert!(lines[0].code.contains("call(\"\"); other();"));
    }

    #[test]
    fn raw_and_byte_strings_do_not_leak() {
        let src = "let a = r#\"has \"quotes\" and unwrap()\"#;\nlet b = b\"panic!\";\nafter();\n";
        let lines = lex(src);
        assert!(!lines[0].code.contains("unwrap"));
        assert!(!lines[1].code.contains("panic!"));
        assert!(lines[2].code.contains("after();"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let lines = lex("let q = '\"'; fn f<'a>(x: &'a str) {} let e = '\\''; let n = '\\n';\n");
        // The double quote inside the char literal must not open a string.
        assert!(lines[0].code.contains("fn f<'a>"));
        assert!(lines[0].code.contains("let n ="));
    }

    #[test]
    fn nested_block_comments() {
        let lines = lex("a(); /* one /* two */ still comment */ b();\n");
        assert!(lines[0].code.contains("a();"));
        assert!(lines[0].code.contains("b();"));
        assert!(!lines[0].code.contains("still"));
        assert!(lines[0].comment.contains("still comment"));
    }

    #[test]
    fn multiline_strings_span_lines() {
        let lines = lex("let s = \"line one\nline two unwrap()\";\ntail();\n");
        assert!(!lines[1].code.contains("unwrap"));
        assert!(lines[2].code.contains("tail();"));
    }

    #[test]
    fn test_regions_cover_cfg_test_and_compound_forms() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n  fn t() { x.unwrap(); }\n}\nfn lib2() {}\n#[cfg(all(test, feature = \"pjrt\"))]\nmod more {\n  fn u() {}\n}\nfn lib3() {}\n";
        let lines = lex(src);
        assert!(!lines[0].in_test);
        assert!(lines[1].in_test, "attribute line");
        assert!(lines[3].in_test, "body line");
        assert!(lines[4].in_test, "closing brace");
        assert!(!lines[5].in_test, "after region");
        assert!(lines[6].in_test, "compound cfg(all(test, …))");
        assert!(lines[8].in_test);
        assert!(!lines[10].in_test);
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("use std::collections::HashMap;", "HashMap"));
        assert!(!has_word("MyHashMapLike", "HashMap"));
        assert!(has_word("#[cfg(all(test, feature = \"\"))]", "test"));
        assert!(!has_word("latest", "test"));
    }
}
