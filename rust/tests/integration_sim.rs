//! End-to-end integration over the simulated serving stack:
//! scheduler -> schedule -> discrete-event simulation -> metrics,
//! across scenarios, sharing modes, and the adaptive reorganizer.

use gpulets::coordinator::simserver::{simulate, SimConfig};
use gpulets::coordinator::AdaptiveServer;
use gpulets::experiments::common::{paper_ctx, violation_rate_of};
use gpulets::gpu::ShareMode;
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{
    ElasticPartitioning, GuidedSelfTuning, Scheduler, SquishyBinPacking,
};
use gpulets::workload::{generate_arrivals, named_scenarios, FluctuationTrace};

fn arrivals_for(rates: &[f64; 5], duration_s: f64, seed: u64) -> Vec<gpulets::workload::Arrival> {
    let pairs: Vec<(ModelId, f64)> = ModelId::ALL
        .iter()
        .map(|&m| (m, rates[m.index()]))
        .filter(|&(_, r)| r > 0.0)
        .collect();
    generate_arrivals(&pairs, duration_s, seed).expect("finite rates")
}

#[test]
fn every_table5_scenario_serves_cleanly_under_gpulet_int() {
    let ctx = paper_ctx(true);
    let scheduler = ElasticPartitioning::gpulet_int();
    for sc in named_scenarios() {
        let schedule = scheduler
            .schedule(&ctx, &sc.rates)
            .unwrap_or_else(|e| panic!("{} must be schedulable: {e}", sc.name));
        schedule.validate(&ctx.lm, 4).unwrap();
        let viol = violation_rate_of(&ctx, &schedule, &sc.rates, 20.0, 7);
        assert!(viol < 0.02, "{}: violation rate {viol}", sc.name);
    }
}

#[test]
fn all_schedulers_produce_simulatable_schedules() {
    let ctx = paper_ctx(false);
    let rates = [50.0, 30.0, 20.0, 10.0, 10.0];
    let schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(SquishyBinPacking::baseline()),
        Box::new(SquishyBinPacking::with_even_partitioning()),
        Box::new(GuidedSelfTuning),
        Box::new(ElasticPartitioning::gpulet()),
    ];
    let arrivals = arrivals_for(&rates, 10.0, 3);
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    for s in schedulers {
        let schedule = s
            .schedule(&ctx, &rates)
            .unwrap_or_else(|e| panic!("{} failed on light load: {e}", s.name()));
        let report = simulate(&lm, &gt, &schedule, &arrivals, 10.0, &SimConfig::default());
        let served: u64 = ModelId::ALL
            .iter()
            .filter_map(|&m| report.model(m))
            .map(|mm| mm.served)
            .sum();
        assert!(
            served as usize >= arrivals.len() * 95 / 100,
            "{}: served only {served}/{}",
            s.name(),
            arrivals.len()
        );
    }
}

#[test]
fn sharing_mode_ordering_holds_under_pressure() {
    // Fig 5's macro claim: static partitioning beats whole-GPU temporal
    // sharing when a short-SLO model is consolidated with a heavy one.
    let ctx = paper_ctx(false);
    let scheduler = ElasticPartitioning::gpulet();
    let rates = [1500.0, 0.0, 0.0, 0.0, 120.0];
    let Ok(schedule) = scheduler.schedule(&ctx, &rates) else {
        panic!("consolidated lenet+vgg load must be schedulable");
    };
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let arrivals = arrivals_for(&rates, 10.0, 11);
    let viol = |mode: ShareMode| {
        simulate(
            &lm, &gt, &schedule, &arrivals, 10.0,
            &SimConfig { mode, ..Default::default() },
        )
        .overall_violation_rate()
    };
    let part = viol(ShareMode::Partitioned);
    let temp = viol(ShareMode::TemporalOnly);
    assert!(part <= temp + 0.02, "partitioned {part} vs temporal {temp}");
}

#[test]
fn requests_are_conserved() {
    // Every arrival is either served or dropped — never lost.
    let ctx = paper_ctx(false);
    let scheduler = ElasticPartitioning::gpulet();
    let rates = [200.0, 100.0, 400.0, 30.0, 250.0]; // over-capacity on purpose
    if let Ok(schedule) = scheduler.schedule(&ctx, &[100.0, 50.0, 50.0, 20.0, 30.0]) {
        let arrivals = arrivals_for(&rates, 8.0, 17);
        let lm = LatencyModel::new();
        let report = simulate(
            &lm,
            &GroundTruth::default(),
            &schedule,
            &arrivals,
            8.0,
            &SimConfig::default(),
        );
        let total: u64 = ModelId::ALL
            .iter()
            .filter_map(|&m| report.model(m))
            .map(|mm| mm.total())
            .sum();
        assert_eq!(total as usize, arrivals.len(), "requests lost or duplicated");
    }
}

#[test]
fn adaptive_server_survives_paper_trace_wave() {
    // The Fig 14 configuration end to end (shortened to one wave).
    let ctx = paper_ctx(false);
    let scheduler = ElasticPartitioning::gpulet();
    let server = AdaptiveServer::new(&ctx, &scheduler);
    let out = server
        .run_trace(&FluctuationTrace::default(), 700.0, 2024)
        .expect("finite trace rates");
    assert_eq!(out.windows.len(), 35);
    let reorgs = out.windows.iter().filter(|w| w.reorganized).count();
    assert!(reorgs >= 2, "expected several reorganizations, got {reorgs}");
    let worst = out
        .windows
        .iter()
        .map(|w| w.violation_rate)
        .fold(0.0f64, f64::max);
    assert!(worst < 0.30, "worst window violation {worst}");
    // The persistent engine conserves requests across every window and
    // re-organization boundary.
    for m in ModelId::ALL {
        let total = out.report.model(m).map_or(0, |mm| mm.total());
        assert_eq!(total, out.offered[m.index()], "{m} lost requests");
    }
}

#[test]
fn interference_aware_not_worse_at_same_rates() {
    let ctx_p = paper_ctx(false);
    let ctx_i = paper_ctx(true);
    let gp = ElasticPartitioning::gpulet();
    let gi = ElasticPartitioning::gpulet_int();
    // A contended mix both accept.
    let rates = [0.0, 150.0, 150.0, 100.0, 150.0];
    let (Ok(sp), Ok(si)) = (gp.schedule(&ctx_p, &rates), gi.schedule(&ctx_i, &rates)) else {
        return; // if either rejects, nothing to compare
    };
    let vp = violation_rate_of(&ctx_p, &sp, &rates, 15.0, 23);
    let vi = violation_rate_of(&ctx_i, &si, &rates, 15.0, 23);
    assert!(vi <= vp + 0.03, "gpulet+int {vi} much worse than gpulet {vp}");
}
