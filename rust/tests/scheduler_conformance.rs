//! Scheduler conformance: the shared invariant battery every registered
//! scheduler runs through automatically. The scheduler list comes from
//! `sched::registry()` — adding a scheduler there auto-enrolls it in
//! every test below, and the `Algo` round-trip test forces the CLI
//! vocabulary to grow with it.
//!
//! Invariants pinned here (tier-1, `cargo test`):
//! * every schedulable verdict across the full 1,023 fig04 scenario
//!   population passes `Schedule::validate` (structure, duty-sum
//!   utilization <= 1.0, duty-cycle feasibility) and covers the offered
//!   load it accepted;
//! * verdicts are identical for any `--threads` worker count;
//! * NaN/negative/infinite rates are rejected at the boundary, never
//!   panicking deep in a sort;
//! * zero load yields an empty schedule, absurd load an informative
//!   `not schedulable` error;
//! * the paper's dominance relations (ideal >= every spatial-only
//!   scheduler, elastic >= SBP on the eval workloads).

use gpulets::config::Algo;
use gpulets::experiments::common::{eval_workloads, max_schedulable, paper_ctx};
use gpulets::models::ModelId;
use gpulets::sched::{registry, ElasticPartitioning, IdealScheduler, SchedCtx, Scheduler, SquishyBinPacking};
use gpulets::util::par::{par_map, par_map_threads};
use gpulets::util::rng::Pcg32;
use gpulets::workload::enumerate_all_scenarios;

/// Context matching the scheduler's own declaration — interference-aware
/// schedulers plan against the fitted model, the rest against latency
/// alone. This is what the CLI does, keyed the same way.
fn ctx_for(s: &dyn Scheduler) -> SchedCtx {
    paper_ctx(s.interference_aware())
}

/// Random rate vectors spanning light to heavy loads.
fn random_rates(rng: &mut Pcg32) -> [f64; 5] {
    let mut rates = [0.0; 5];
    for r in rates.iter_mut() {
        if rng.f64() < 0.7 {
            *r = rng.range(0.0, 400.0);
        }
    }
    rates
}

#[test]
fn every_schedulable_verdict_validates_across_the_fig04_population() {
    let scenarios = enumerate_all_scenarios();
    assert_eq!(scenarios.len(), 1023);
    for s in registry() {
        let ctx = ctx_for(s.as_ref());
        // Scenario verdicts are independent: fan out over the worker
        // pool and collect any invariant breach as a message.
        let failures: Vec<String> = par_map(&scenarios, |sc| {
            let schedule = match s.schedule(&ctx, &sc.rates) {
                Ok(schedule) => schedule,
                Err(_) => return None,
            };
            if let Err(e) = schedule.validate(&ctx.lm, ctx.num_gpus) {
                return Some(format!("{}: {}: invalid schedule: {e}", s.name(), sc.name));
            }
            let assigned = schedule.assigned_rates();
            for m in ModelId::ALL {
                if assigned[m.index()] < sc.rates[m.index()] - 1e-6 {
                    return Some(format!(
                        "{}: {}: {m} assigned {} < offered {}",
                        s.name(),
                        sc.name,
                        assigned[m.index()],
                        sc.rates[m.index()]
                    ));
                }
            }
            None
        })
        .into_iter()
        .flatten()
        .collect();
        assert!(failures.is_empty(), "{}", failures.join("\n"));
    }
}

#[test]
fn verdicts_are_deterministic_across_thread_counts() {
    // A deterministic sample of the population plus random mixed loads;
    // each scheduler's verdict digests must be byte-identical whether
    // the sweep runs on 1 worker or several (`--threads N` contract).
    let scenarios = enumerate_all_scenarios();
    let mut cases: Vec<[f64; 5]> = scenarios.iter().step_by(17).map(|sc| sc.rates).collect();
    let mut rng = Pcg32::seeded(0xBEEF);
    cases.extend((0..10).map(|_| random_rates(&mut rng)));
    for s in registry() {
        let ctx = ctx_for(s.as_ref());
        let digest = |workers: usize| -> Vec<String> {
            par_map_threads(workers, &cases, |rates| match s.schedule(&ctx, rates) {
                Ok(schedule) => format!("ok {:?}", schedule.lets),
                Err(e) => format!("err {e}"),
            })
        };
        let serial = digest(1);
        for workers in [2, 5] {
            assert_eq!(
                serial,
                digest(workers),
                "{}: verdicts changed between 1 and {workers} workers",
                s.name()
            );
        }
    }
}

#[test]
fn nan_and_negative_rates_are_rejected_at_the_boundary() {
    for s in registry() {
        let ctx = ctx_for(s.as_ref());
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0] {
            let mut rates = [10.0; 5];
            rates[2] = bad;
            let err = s.schedule(&ctx, &rates).unwrap_err();
            assert!(
                err.to_string().contains("invalid request rate"),
                "{}: rate {bad} gave {err}",
                s.name()
            );
        }
    }
}

#[test]
fn registry_names_round_trip_through_the_cli_vocabulary() {
    // Auto-enrollment coupling: every registered scheduler must be
    // reachable from the CLI (`Algo::parse(name)`), and the algo must
    // instantiate a scheduler of the same name. A scheduler added to
    // `sched::registry()` without an `Algo` variant fails here.
    let reg = registry();
    let mut names: Vec<&str> = reg.iter().map(|s| s.name()).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), reg.len(), "duplicate scheduler names in registry");
    for s in &reg {
        let algo = Algo::parse(s.name())
            .unwrap_or_else(|e| panic!("{}: not in the CLI vocabulary: {e}", s.name()));
        assert_eq!(algo.name(), s.name());
        assert_eq!(algo.scheduler().name(), s.name());
    }
}

#[test]
fn zero_load_yields_empty_schedule_for_all() {
    for s in registry() {
        let ctx = ctx_for(s.as_ref());
        let schedule = s.schedule(&ctx, &[0.0; 5]).unwrap();
        assert!(schedule.lets.is_empty(), "{}: non-empty for zero load", s.name());
    }
}

#[test]
fn ideal_dominates_every_spatial_scheduler_on_sampled_scenarios() {
    let ideal = IdealScheduler;
    let ctx = paper_ctx(false);
    // Deterministic sample of the 1023-scenario population (full sweep
    // is the fig15 bench).
    let scenarios = enumerate_all_scenarios();
    let sample: Vec<_> = scenarios.iter().step_by(23).collect();
    for s in registry() {
        // `ideal` trivially dominates itself; `spacetime` legitimately
        // escapes the comparison — temporal packing admits loads outside
        // ideal's purely spatial search space.
        if s.name() == "ideal" || s.name() == "spacetime" {
            continue;
        }
        let sctx = ctx_for(s.as_ref());
        for sc in &sample {
            if s.schedule(&sctx, &sc.rates).is_ok() {
                assert!(
                    ideal.schedule(&ctx, &sc.rates).is_ok(),
                    "{} schedules {} but ideal does not",
                    s.name(),
                    sc.name
                );
            }
        }
    }
}

#[test]
fn elastic_schedulability_at_least_sbp_on_eval_workloads() {
    // The throughput headline at the admission level: elastic must accept
    // at least the scale SBP accepts on every evaluation workload.
    let ctx = paper_ctx(false);
    let sbp = SquishyBinPacking::baseline();
    let gp = ElasticPartitioning::gpulet();
    for (name, base) in eval_workloads() {
        let k_sbp = max_schedulable(&ctx, &sbp, &base);
        let k_gp = max_schedulable(&ctx, &gp, &base);
        assert!(
            k_gp >= k_sbp * 0.95,
            "{name}: gpulet scale {k_gp} < sbp {k_sbp}"
        );
    }
}

#[test]
fn not_schedulable_error_is_informative() {
    for s in registry() {
        let ctx = ctx_for(s.as_ref());
        let err = s.schedule(&ctx, &[1e9; 5]).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("not schedulable"),
            "{}: unexpected error {msg:?}",
            s.name()
        );
    }
}
