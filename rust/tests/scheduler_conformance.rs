//! Scheduler conformance: every scheduler must produce structurally
//! valid schedules, cover the offered load it accepted, and respect the
//! dominance relations the paper reports (ideal >= elastic >= the
//! baselines on schedulability).

use gpulets::experiments::common::{max_schedulable, paper_ctx};
use gpulets::models::ModelId;
use gpulets::sched::{
    ElasticPartitioning, GuidedSelfTuning, IdealScheduler, SchedCtx, Scheduler,
    SquishyBinPacking,
};
use gpulets::util::rng::Pcg32;
use gpulets::workload::enumerate_all_scenarios;

fn all_schedulers() -> Vec<Box<dyn Scheduler>> {
    vec![
        Box::new(SquishyBinPacking::baseline()),
        Box::new(SquishyBinPacking::with_even_partitioning()),
        Box::new(GuidedSelfTuning),
        Box::new(ElasticPartitioning::gpulet()),
        Box::new(ElasticPartitioning::gpulet_int()),
        Box::new(IdealScheduler),
    ]
}

fn ctx_for(s: &dyn Scheduler) -> SchedCtx {
    paper_ctx(s.name() == "gpulet+int")
}

/// Random rate vectors spanning light to heavy loads.
fn random_rates(rng: &mut Pcg32) -> [f64; 5] {
    let mut rates = [0.0; 5];
    for r in rates.iter_mut() {
        if rng.f64() < 0.7 {
            *r = rng.range(0.0, 400.0);
        }
    }
    rates
}

#[test]
fn accepted_schedules_are_valid_and_cover_offered_load() {
    let mut rng = Pcg32::seeded(0xC0DE);
    let cases: Vec<[f64; 5]> = (0..40).map(|_| random_rates(&mut rng)).collect();
    for s in all_schedulers() {
        let ctx = ctx_for(s.as_ref());
        for rates in &cases {
            let Ok(schedule) = s.schedule(&ctx, rates) else { continue };
            schedule
                .validate(&ctx.lm, ctx.num_gpus)
                .unwrap_or_else(|e| panic!("{}: invalid schedule: {e}", s.name()));
            let assigned = schedule.assigned_rates();
            for m in ModelId::ALL {
                assert!(
                    assigned[m.index()] >= rates[m.index()] - 1e-6,
                    "{}: {m} assigned {} < offered {}",
                    s.name(),
                    assigned[m.index()],
                    rates[m.index()]
                );
            }
        }
    }
}

#[test]
fn zero_load_yields_empty_schedule_for_all() {
    for s in all_schedulers() {
        let ctx = ctx_for(s.as_ref());
        let schedule = s.schedule(&ctx, &[0.0; 5]).unwrap();
        assert!(schedule.lets.is_empty(), "{}: non-empty for zero load", s.name());
    }
}

#[test]
fn ideal_dominates_every_practical_scheduler_on_sampled_scenarios() {
    let ideal = IdealScheduler;
    let ctx = paper_ctx(false);
    // Deterministic sample of the 1023-scenario population (full sweep
    // is the fig15 bench).
    let scenarios = enumerate_all_scenarios();
    let sample: Vec<_> = scenarios.iter().step_by(23).collect();
    for s in all_schedulers() {
        if s.name() == "ideal" {
            continue;
        }
        let sctx = ctx_for(s.as_ref());
        for sc in &sample {
            if s.schedule(&sctx, &sc.rates).is_ok() {
                assert!(
                    ideal.schedule(&ctx, &sc.rates).is_ok(),
                    "{} schedules {} but ideal does not",
                    s.name(),
                    sc.name
                );
            }
        }
    }
}

#[test]
fn elastic_schedulability_at_least_sbp_on_eval_workloads() {
    // The throughput headline at the admission level: elastic must accept
    // at least the scale SBP accepts on every evaluation workload.
    let ctx = paper_ctx(false);
    let sbp = SquishyBinPacking::baseline();
    let gp = ElasticPartitioning::gpulet();
    for (name, base) in gpulets::experiments::common::eval_workloads() {
        let k_sbp = max_schedulable(&ctx, &sbp, &base);
        let k_gp = max_schedulable(&ctx, &gp, &base);
        assert!(
            k_gp >= k_sbp * 0.95,
            "{name}: gpulet scale {k_gp} < sbp {k_sbp}"
        );
    }
}

#[test]
fn schedulers_are_deterministic() {
    let mut rng = Pcg32::seeded(0xBEEF);
    let rates = random_rates(&mut rng);
    for s in all_schedulers() {
        let ctx = ctx_for(s.as_ref());
        let a = s.schedule(&ctx, &rates).ok().map(|s| format!("{:?}", s.lets));
        let b = s.schedule(&ctx, &rates).ok().map(|s| format!("{:?}", s.lets));
        assert_eq!(a, b, "{}: nondeterministic schedule", s.name());
    }
}

#[test]
fn not_schedulable_error_is_informative() {
    for s in all_schedulers() {
        let ctx = ctx_for(s.as_ref());
        let err = s.schedule(&ctx, &[1e9; 5]).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("not schedulable"),
            "{}: unexpected error {msg:?}",
            s.name()
        );
    }
}
