//! Round-trip coverage for the config/report serialization path the
//! `gpulets` CLI depends on: `util::tomlmini` (config in) and
//! `util::json` (BENCH reports out), including randomized documents via
//! `util::proptest_mini`.

use gpulets::config::Config;
use gpulets::util::benchkit::{self, BenchResult};
use gpulets::util::json::{obj, Json};
use gpulets::util::proptest_mini::{run, Config as PropConfig};
use gpulets::util::rng::Pcg32;
use gpulets::util::tomlmini::{TomlDoc, TomlValue};

// ---- TOML ----------------------------------------------------------------

#[test]
fn toml_doc_round_trips_through_render() {
    let text = r#"
name = "paper"
[gpu]
count = 4
sizes = [20, 40, 50, 60, 80, 100]
[sched]
algo = "gpulet+int"
period_s = 20.0
interference = true
[sched.limits]
max_rounds = 64
[rates]
lenet = 50.0
vgg = 12.5
"#;
    let doc = TomlDoc::parse(text).unwrap();
    let rendered = doc.to_toml();
    let doc2 = TomlDoc::parse(&rendered).unwrap();
    // Same dotted keys, same values, same types.
    assert_eq!(doc.to_toml(), doc2.to_toml());
    assert_eq!(doc2.get("gpu.count").unwrap(), &TomlValue::Int(4));
    assert_eq!(doc2.get("sched.period_s").unwrap(), &TomlValue::Float(20.0));
    assert_eq!(doc2.get("sched.limits.max_rounds").unwrap(), &TomlValue::Int(64));
    assert_eq!(doc2.get("rates.vgg").unwrap(), &TomlValue::Float(12.5));
}

#[test]
fn config_survives_a_render_round_trip() {
    let text = r#"
[gpu]
count = 2
share_mode = "temporal"
[sched]
algo = "sbp"
period_s = 10.0
[workload]
duration_s = 60.0
seed = 7
[rates]
lenet = 100.0
vgg = 25.0
"#;
    let direct = Config::parse(text).unwrap();
    let rendered = TomlDoc::parse(text).unwrap().to_toml();
    let via_render = Config::parse(&rendered).unwrap();
    assert_eq!(direct.num_gpus, via_render.num_gpus);
    assert_eq!(direct.algo, via_render.algo);
    assert_eq!(direct.share_mode, via_render.share_mode);
    assert_eq!(direct.duration_s, via_render.duration_s);
    assert_eq!(direct.seed, via_render.seed);
    assert_eq!(direct.rates, via_render.rates);
}

#[test]
fn prop_random_toml_docs_round_trip() {
    fn random_value(rng: &mut Pcg32, depth: usize) -> TomlValue {
        match rng.below(if depth == 0 { 5 } else { 4 }) {
            0 => TomlValue::Int(rng.next_u32() as i64 - (u32::MAX / 2) as i64),
            1 => {
                // Finite, exactly representable round numbers.
                TomlValue::Float((rng.next_u32() % 10_000) as f64 / 4.0)
            }
            2 => TomlValue::Bool(rng.f64() < 0.5),
            3 => {
                let n = rng.below(8) + 1;
                TomlValue::Str(
                    (0..n)
                        .map(|_| (b'a' + rng.below(26) as u8) as char)
                        .collect(),
                )
            }
            _ => {
                let n = rng.below(4);
                TomlValue::Arr((0..n).map(|_| random_value(rng, depth + 1)).collect())
            }
        }
    }

    run(
        PropConfig { cases: 100, seed: 0x70117, ..Default::default() },
        |rng| {
            let mut doc = TomlDoc::default();
            let n = rng.below(12) + 1;
            for i in 0..n {
                let path = match rng.below(3) {
                    0 => format!("key{i}"),
                    1 => format!("sec{}.key{i}", rng.below(3)),
                    _ => format!("sec{}.sub{}.key{i}", rng.below(2), rng.below(2)),
                };
                doc.set(path, random_value(rng, 0));
            }
            doc.to_toml()
        },
        |_| vec![],
        |text| {
            let a = TomlDoc::parse(text).map_err(|e| format!("parse 1: {e}"))?;
            let b = TomlDoc::parse(&a.to_toml()).map_err(|e| format!("parse 2: {e}"))?;
            if a.to_toml() != b.to_toml() {
                return Err(format!("unstable round trip:\n{}\nvs\n{}", a.to_toml(), b.to_toml()));
            }
            Ok(())
        },
    );
}

// ---- JSON ----------------------------------------------------------------

#[test]
fn json_bench_report_round_trips_through_disk() {
    let timing = BenchResult {
        name: "fig12: 4-scheduler max-throughput search".into(),
        iters: 1,
        mean_ms: 1234.5,
        min_ms: 1234.5,
        max_ms: 1234.5,
    };
    let payload = obj(vec![
        ("figure", Json::Str("fig12".into())),
        (
            "workloads",
            Json::Arr(vec![obj(vec![
                ("workload", Json::Str("equal".into())),
                ("throughput_rps", Json::Num(812.0)),
                ("violation_rate", Json::Num(0.0042)),
            ])]),
        ),
    ]);
    let doc = benchkit::envelope(&timing, payload);

    let path = std::env::temp_dir().join("gpulets_roundtrip_BENCH_test.json");
    benchkit::write_json(&path, &doc).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    let parsed = Json::parse(text.trim()).unwrap();
    assert_eq!(parsed, doc, "disk round trip must be lossless");
    let wl = &parsed.get("result").unwrap().get("workloads").unwrap().as_arr().unwrap()[0];
    assert_eq!(wl.get("workload").unwrap().as_str().unwrap(), "equal");
    assert_eq!(wl.get("violation_rate").unwrap().as_f64().unwrap(), 0.0042);
}

#[test]
fn prop_random_json_values_round_trip() {
    fn random_json(rng: &mut Pcg32, depth: usize) -> Json {
        match rng.below(if depth >= 2 { 4 } else { 6 }) {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.next_u32() as f64 - (u32::MAX / 2) as f64) / 8.0),
            3 => {
                let n = rng.below(10);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            // Mix in characters the escaper must handle.
                            const POOL: &[char] =
                                &['a', 'Z', '9', '"', '\\', '\n', '\t', 'é', '∂', ' '];
                            POOL[rng.below(POOL.len())]
                        })
                        .collect(),
                )
            }
            4 => {
                let n = rng.below(5);
                Json::Arr((0..n).map(|_| random_json(rng, depth + 1)).collect())
            }
            _ => {
                let n = rng.below(5);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                        .collect(),
                )
            }
        }
    }

    run(
        PropConfig { cases: 200, seed: 0x15011, ..Default::default() },
        |rng| random_json(rng, 0),
        |_| vec![],
        |v| {
            let text = v.to_string();
            let back = Json::parse(&text).map_err(|e| format!("reparse failed: {e}\n{text}"))?;
            if &back != v {
                return Err(format!("round trip changed value:\n{text}\nvs\n{}", back.to_string()));
            }
            Ok(())
        },
    );
}
