//! Equivalence proofs for the flattened scheduling hot paths: the
//! memoized capacity table, the ideal scheduler's layout-multiset
//! dedup, and the parallel experiment sweeps must all be *pure*
//! optimizations — identical results, only faster.

use gpulets::experiments::{common::paper_ctx, fig04};
use gpulets::models::ModelId;
use gpulets::perfmodel::latency::knee;
use gpulets::perfmodel::profile_table::PARTITIONS;
use gpulets::perfmodel::{CapacityTable, LatencyModel};
use gpulets::sched::types::SLO_PLANNING_SCALE;
use gpulets::sched::{
    ElasticPartitioning, GuidedSelfTuning, IdealScheduler, SchedCtx, Scheduler,
    SquishyBinPacking,
};
use gpulets::util::par;
use gpulets::workload::enumerate_all_scenarios;

/// The capacity table must agree with `LatencyModel::max_rate` /
/// `max_batch_within` on every (model, partition) grid point, for both
/// the planning-margin view and the unmargined one.
#[test]
fn capacity_table_matches_latency_model_on_every_grid_point() {
    for lm in [LatencyModel::new(), LatencyModel::with_slo_scale(SLO_PLANNING_SCALE)] {
        let cap = CapacityTable::build(&lm);
        for m in ModelId::ALL {
            for &pct in &PARTITIONS {
                let p = pct as f64 / 100.0;
                assert_eq!(
                    cap.lookup_rate(m, pct).unwrap(),
                    lm.max_rate(m, p),
                    "{m} p={pct}: max_rate memo diverged"
                );
                assert_eq!(
                    cap.lookup_half_slo_batch(m, pct).unwrap(),
                    lm.max_batch_within(m, p, lm.slo_ms(m) / 2.0),
                    "{m} p={pct}: best-batch memo diverged"
                );
            }
            assert_eq!(
                cap.knee_pct(m),
                knee(&lm.rate_curve(m, &PARTITIONS)),
                "{m}: knee memo diverged"
            );
        }
    }
}

/// `SchedCtx::max_rate` must be exact on the grid and fall back to the
/// latency model off it.
#[test]
fn sched_ctx_lookup_falls_back_off_grid() {
    let ctx = SchedCtx::new(4, None);
    for m in ModelId::ALL {
        for pct in [20u32, 40, 50, 60, 80, 100, 30, 70, 99] {
            assert_eq!(ctx.max_rate(m, pct), ctx.lm.max_rate(m, pct as f64 / 100.0));
        }
    }
}

/// Layout-multiset symmetry: the deduplicated ideal search must return
/// the same schedulability verdict as the full 4^N enumeration on the
/// whole 1,023-scenario population (paper testbed, 4 GPUs).
#[test]
fn ideal_dedup_matches_full_enumeration_on_population() {
    let ctx = paper_ctx(false);
    let scenarios = enumerate_all_scenarios();
    let mismatches: Vec<String> = par::par_map(&scenarios, |sc| {
        let dedup = IdealScheduler::schedule_with(&ctx, &sc.rates, true).is_ok();
        let full = IdealScheduler::schedule_with(&ctx, &sc.rates, false).is_ok();
        if dedup != full {
            Some(format!("{}: dedup={dedup} full={full}", sc.name))
        } else {
            None
        }
    })
    .into_iter()
    .flatten()
    .collect();
    assert!(mismatches.is_empty(), "verdict mismatches: {mismatches:?}");
}

/// The parallel sweep must produce byte-identical JSON to `--threads 1`
/// (deterministic merge order), and `par_map` itself must be
/// order-stable for a compute-heavy scheduling workload.
#[test]
fn parallel_sweep_is_byte_identical_to_serial() {
    // Whole-figure check: fig04's 1,023-scenario sweep, serialized.
    par::set_threads(1);
    let serial = fig04::report().payload.to_string();
    par::set_threads(4);
    let parallel = fig04::report().payload.to_string();
    par::set_threads(0); // restore auto
    assert_eq!(serial, parallel, "fig04 payload differs across thread counts");

    // Direct check on the primitive with per-scenario verdicts.
    let ctx = paper_ctx(false);
    let scenarios = enumerate_all_scenarios();
    let sample: Vec<_> = scenarios.into_iter().step_by(11).collect();
    let sched = SquishyBinPacking::baseline();
    let one = par::par_map_threads(1, &sample, |sc| sched.schedule(&ctx, &sc.rates).is_ok());
    let many = par::par_map_threads(8, &sample, |sc| sched.schedule(&ctx, &sc.rates).is_ok());
    assert_eq!(one, many);
}

/// Satellite: non-finite rates must be rejected with a proper error at
/// every scheduler's boundary — not panic in the rate-descending sort.
#[test]
fn non_finite_rates_rejected_with_error() {
    let schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(SquishyBinPacking::baseline()),
        Box::new(SquishyBinPacking::with_even_partitioning()),
        Box::new(GuidedSelfTuning),
        Box::new(ElasticPartitioning::gpulet()),
        Box::new(ElasticPartitioning::gpulet_int()),
        Box::new(IdealScheduler),
    ];
    for s in &schedulers {
        let ctx = paper_ctx(s.name() == "gpulet+int");
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -5.0] {
            let mut rates = [50.0; 5];
            rates[1] = bad;
            let err = s
                .schedule(&ctx, &rates)
                .expect_err(&format!("{}: accepted rate {bad}", s.name()));
            assert!(
                err.to_string().contains("invalid request rate"),
                "{}: unexpected error {err}",
                s.name()
            );
        }
    }
}
