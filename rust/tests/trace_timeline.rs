//! The PR 10 telemetry battery: the merged [`Timeline`] on
//! `FleetOutcome` must be a *sound* record of a faulted fleet run, not
//! a best-effort log.
//!
//! Pinned here (tier-1, `cargo test`):
//! * the exact event ledger reconciles with every `FleetOutcome`
//!   counter — deal/arrival vs offered, shed vs shed, batch-done vs
//!   served, drop+timeout vs dropped, lost vs lost_to_failure — on a
//!   run that sheds, kills a node mid-flash and recovers it;
//! * batch weight-matching per (node, gpu-let): no batch finishes that
//!   never started, and every unmatched start is covered by lost or
//!   dropped work on the same gpu-let;
//! * the fault markers bracket a genuinely silent node: zero events
//!   carry the dead node's index strictly between its `node-down` and
//!   `node-up` marks, and the node traces again after recovery;
//! * swap epochs are strictly monotone per node;
//! * the per-window gauge series sums to the routing totals and
//!   observes the outage (alive dips by one, then recovers);
//! * the serialized Chrome-trace export — full capture *and* span-
//!   sampled — is byte-identical across worker counts {1, 2, 5}, and
//!   sampling thins only the event list, never the ledger.
//!
//! Thread settings are process-global; see `fleet_equivalence.rs` for
//! why racing `set_threads` calls are benign here.

use std::collections::BTreeMap;

use gpulets::fleet::{AdmissionMode, AdmissionSpec, FleetConfig, FleetEngine, FleetPlanner};
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{ElasticPartitioning, SchedCtx};
use gpulets::telemetry::{export, EventKind, Timeline, NO_NODE};
use gpulets::workload::{
    dyn_sources, poisson_streams, DynSourceMux, FaultEvent, FaultKind, FaultPlan, SourceMux,
};

const TRACE_CAP: usize = 1 << 18;
const DEAD_NODE: usize = 1;

fn mux_for(pairs: &[(ModelId, f64)], duration_s: f64, seed: u64) -> DynSourceMux {
    SourceMux::new(dyn_sources(poisson_streams(pairs, duration_s, seed).unwrap()))
}

/// One faulted, gated, traced fleet run: 4 nodes, node 1 down at 2 s
/// and back at 4 s, shed gate armed, auto-rebalance on.
fn traced_run(sample_n: u64) -> gpulets::fleet::FleetOutcome {
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let ctx = SchedCtx::new(4, None);
    let scheduler = ElasticPartitioning::gpulet();
    let rates = [300.0, 0.0, 90.0, 0.0, 60.0];
    let pairs = [
        (ModelId::Lenet, 300.0),
        (ModelId::Resnet, 90.0),
        (ModelId::Vgg, 60.0),
    ];
    let duration = 6.0;
    let planner = FleetPlanner::new(&ctx, &scheduler, 4);
    let plan = planner.plan(&rates).unwrap();
    let cfg = FleetConfig {
        window_s: 1.0,
        rebalance: true,
        trace_cap: TRACE_CAP,
        trace_sample: sample_n,
        ..Default::default()
    };
    let mut fleet = FleetEngine::new(
        &lm,
        &gt,
        planner,
        plan,
        mux_for(&pairs, duration, 23),
        duration,
        &cfg,
    );
    fleet
        .set_fault_plan(
            FaultPlan::new(vec![
                FaultEvent { at_s: 2.0, node: DEAD_NODE, kind: FaultKind::Down },
                FaultEvent { at_s: 4.0, node: DEAD_NODE, kind: FaultKind::Up },
            ])
            .unwrap(),
        )
        .unwrap();
    // Headroom well under the offered load so the gate demonstrably
    // sheds (capacity == the planned rates; demand tracks the full
    // rates, so 0.6 * capacity is always exceeded once the gate arms).
    fleet.set_admission(AdmissionSpec {
        mode: AdmissionMode::Shed,
        headroom: 0.6,
        ..AdmissionSpec::default()
    });
    fleet.run(duration);
    fleet.finish()
}

fn sum(xs: &[u64; 5]) -> u64 {
    xs.iter().sum()
}

#[test]
fn fault_timeline_reconciles_and_respects_the_outage() {
    let out = traced_run(1);
    let tl = &out.timeline;
    assert!(!tl.is_empty(), "tracing was armed, the timeline must not be empty");
    assert_eq!(tl.dropped_events, 0, "the ring must not overflow at this scale");
    assert_eq!(tl.sample_n, 1);

    // --- Ledger reconciliation: the exact (pre-sampling, n-weighted)
    // event counts against the independently-maintained outcome
    // counters. Every identity is exact, not approximate.
    let (served, dropped) = out.served_dropped();
    assert_eq!(tl.count(EventKind::Deal), sum(&out.offered), "deal vs dealt");
    assert_eq!(tl.count(EventKind::Arrival), sum(&out.offered), "arrival vs dealt");
    assert_eq!(tl.count(EventKind::Admit), sum(&out.offered), "admit vs dealt (shed gate)");
    assert_eq!(tl.count(EventKind::Shed), sum(&out.shed), "shed vs shed");
    assert!(sum(&out.shed) > 0, "the flash crowd over a dead node must shed something");
    assert_eq!(tl.count(EventKind::Degrade), 0, "shed mode never degrades");
    assert_eq!(tl.count(EventKind::BatchDone), sum(&served), "batch-done vs served");
    assert_eq!(
        tl.count(EventKind::Drop) + tl.count(EventKind::Timeout),
        sum(&dropped),
        "drop + timeout vs dropped"
    );
    let lost = out.lost_to_failure();
    assert_eq!(tl.count(EventKind::Lost), sum(&lost), "lost vs lost_to_failure");
    assert!(sum(&lost) > 0, "the outage must destroy queued/in-flight work");
    assert_eq!(
        tl.count(EventKind::BatchForm),
        tl.count(EventKind::BatchStart),
        "every formed batch starts"
    );

    // --- Batch weight-matching per (node, gpu-let): done never exceeds
    // started, and unmatched starts are covered by lost / dropped work
    // on the same gpu-let (in-flight batches destroyed by the failure).
    let mut per_let: BTreeMap<(u32, u32), [u64; 4]> = BTreeMap::new();
    for ev in &tl.events {
        let slot = match ev.kind {
            EventKind::BatchStart => 0,
            EventKind::BatchDone => 1,
            EventKind::Lost => 2,
            EventKind::Drop => 3,
            _ => continue,
        };
        per_let.entry((ev.node, ev.let_idx)).or_insert([0; 4])[slot] += ev.n as u64;
    }
    let mut started_total = 0u64;
    for (&(node, let_idx), &[started, done, lost, drop]) in &per_let {
        started_total += started;
        assert!(
            done <= started,
            "node {node} let {let_idx}: {done} done > {started} started"
        );
        assert!(
            started <= done + lost + drop,
            "node {node} let {let_idx}: {} unmatched starts exceed lost {lost} + drop {drop}",
            started - done
        );
    }
    assert!(started_total > 0, "the run must trace batches");

    // --- The fault markers bracket a silent node: the down/up marks
    // exist (fleet scope, the node in `id`), and *no* event carries the
    // dead node's index strictly inside the outage.
    let marks: Vec<(u64, EventKind)> = tl
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::NodeDown | EventKind::NodeUp))
        .map(|e| {
            assert_eq!(e.node, NO_NODE, "fault marks are fleet-scoped");
            assert_eq!(e.id, DEAD_NODE as u64, "only node 1 faults in this script");
            (e.t_us, e.kind)
        })
        .collect();
    assert_eq!(marks.len(), 2, "exactly one down and one up mark: {marks:?}");
    let (down_t, up_t) = (marks[0].0, marks[1].0);
    assert_eq!(marks[0].1, EventKind::NodeDown);
    assert_eq!(marks[1].1, EventKind::NodeUp);
    assert!(down_t < up_t, "down must precede up");
    let node = DEAD_NODE as u32;
    let inside: Vec<_> = tl
        .events
        .iter()
        .filter(|e| e.node == node && e.t_us > down_t && e.t_us < up_t)
        .collect();
    assert!(inside.is_empty(), "dead node traced during its outage: {inside:?}");
    assert!(
        tl.events.iter().any(|e| e.node == node && e.t_us < down_t),
        "node 1 must trace before the failure"
    );
    assert!(
        tl.events.iter().any(|e| e.node == node && e.t_us > up_t),
        "node 1 must trace again after recovery"
    );

    // --- Swap epochs strictly monotone per node: each swap installs a
    // strictly newer epoch (failures bump the epoch without a swap
    // mark, so gaps are fine — regressions are not).
    let mut last_epoch: BTreeMap<u32, u32> = BTreeMap::new();
    for ev in tl.events.iter().filter(|e| e.kind == EventKind::Swap) {
        if let Some(&prev) = last_epoch.get(&ev.node) {
            assert!(
                ev.epoch > prev,
                "node {}: swap epoch {} after {} at t={}",
                ev.node,
                ev.epoch,
                prev,
                ev.t_us
            );
        }
        last_epoch.insert(ev.node, ev.epoch);
    }
    assert!(!last_epoch.is_empty(), "the recovery re-plan must swap schedules");

    // --- The gauge series observed the outage and sums to the routing
    // totals (the catch-up window keeps the sum exact past the nominal
    // end).
    assert!(tl.windows.len() >= 6, "one gauge snapshot per lockstep window");
    assert!(tl.windows.iter().all(|w| w.nodes.len() == 4));
    let min_alive = tl.windows.iter().map(|w| w.alive).min().unwrap();
    assert_eq!(min_alive, 3, "the outage window must gauge 3/4 alive");
    assert_eq!(tl.windows.last().unwrap().alive, 4, "recovered by the end");
    for m in ModelId::ALL {
        let i = m.index();
        let dealt: u64 = tl.windows.iter().map(|w| w.deals[i]).sum();
        assert_eq!(dealt, out.offered[i], "{m}: window deals must sum to offered");
    }
}

/// The determinism bar for the whole telemetry layer: the *serialized
/// exports* — Chrome-trace JSON and the gauge CSV — are byte-identical
/// across worker-thread counts, at full capture and under span
/// sampling; and sampling thins only the event list, never the exact
/// ledger.
#[test]
fn traces_are_byte_identical_across_thread_counts() {
    let export_bytes = |threads: usize, sample_n: u64| {
        gpulets::util::par::set_threads(threads);
        let out = traced_run(sample_n);
        let tl: &Timeline = &out.timeline;
        let mut s = export::chrome_trace(tl).to_string();
        s.push('\n');
        s.push_str(&export::gauges_csv(tl));
        (s, tl.counts, tl.events.len())
    };

    let (full, full_counts, full_events) = export_bytes(1, 1);
    let (sampled, sampled_counts, sampled_events) = export_bytes(1, 64);
    assert_eq!(
        full_counts, sampled_counts,
        "sampling must never touch the exact ledger"
    );
    assert!(
        sampled_events < full_events,
        "1/64 sampling must thin the event list ({sampled_events} vs {full_events})"
    );
    assert_ne!(full, sampled, "the sampled export records its own modulus");

    for threads in [2usize, 5] {
        let (f, _, _) = export_bytes(threads, 1);
        assert_eq!(full, f, "full trace diverged between 1 and {threads} workers");
        let (s, _, _) = export_bytes(threads, 64);
        assert_eq!(sampled, s, "sampled trace diverged between 1 and {threads} workers");
    }
    gpulets::util::par::set_threads(0);
}
