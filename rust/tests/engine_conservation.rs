//! Request conservation across the continuous-time adaptive serving
//! path (the fix for the restart-the-world state loss at window
//! boundaries): over a Fig-14 trace with multiple re-organizations,
//! every arrival is served or dropped exactly once — never lost at a
//! window cut or schedule swap, never served twice (the engine's
//! debug-build double-serve guard arms inside these runs) — and the
//! whole path is deterministic given a seed. PR 9 adds the failure
//! path: `ServingEngine::fail()` destroys work *counted* (the identity
//! grows a `lost_to_failure` term), never silently.

use gpulets::coordinator::{AdaptiveServer, ServingEngine, SimConfig, SwapMode};
use gpulets::experiments::common::paper_ctx;
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{ElasticPartitioning, SchedCtx, Scheduler, SpaceTimeScheduler};
use gpulets::workload::{dyn_sources, poisson_streams, FluctuationTrace, SourceMux};

#[test]
fn conservation_across_reorganizations() {
    let ctx = paper_ctx(false);
    let scheduler = ElasticPartitioning::gpulet();
    let server = AdaptiveServer::new(&ctx, &scheduler);
    // 900 s covers wave-1 rise, peak, and fall: partitions both grow
    // and shrink, so queued work crosses several swap boundaries.
    let out = server
        .run_trace(&FluctuationTrace::default(), 900.0, 2024)
        .expect("finite trace rates");

    let reorgs = out.windows.iter().filter(|w| w.reorganized).count();
    assert!(reorgs >= 3, "need >= 3 reorganization boundaries, got {reorgs}");

    // Exact conservation, per model: offered == served + dropped.
    let mut offered_total = 0u64;
    for m in ModelId::ALL {
        let offered = out.offered[m.index()];
        let (served, dropped) = out
            .report
            .model(m)
            .map_or((0, 0), |mm| (mm.served, mm.dropped));
        assert_eq!(
            served + dropped,
            offered,
            "{m}: served {served} + dropped {dropped} != offered {offered}"
        );
        offered_total += offered;
    }
    assert!(offered_total > 10_000, "trace should offer real load");

    // The adaptive run must stay in the paper-plausible violation band
    // (the paper reports 0.14% over the full trace).
    let share = out.overall_violation_share();
    assert!(share < 0.08, "whole-trace violation share {share}");
}

#[test]
fn adaptive_path_deterministic_given_seed() {
    let ctx = paper_ctx(false);
    let scheduler = ElasticPartitioning::gpulet();
    let server = AdaptiveServer::new(&ctx, &scheduler);
    let a = server
        .run_trace(&FluctuationTrace::default(), 300.0, 7)
        .expect("finite trace rates");
    let b = server
        .run_trace(&FluctuationTrace::default(), 300.0, 7)
        .expect("finite trace rates");
    assert_eq!(a.windows, b.windows);
    assert_eq!(a.offered, b.offered);
    assert_eq!(a.report.to_json().to_string(), b.report.to_json().to_string());
    // A different seed must actually change the trace.
    let c = server
        .run_trace(&FluctuationTrace::default(), 300.0, 8)
        .expect("finite trace rates");
    assert_ne!(
        a.report.to_json().to_string(),
        c.report.to_json().to_string()
    );
}

#[test]
fn temporally_shared_schedule_conserves_across_mid_trace_swaps() {
    // A time-sliced schedule (two models sharing one gpu-let's duty
    // cycle) through the raw `ServingEngine`: swap to a spatial layout
    // mid-trace and back again, with queued + in-flight work crossing
    // both boundaries. Conservation must stay exact per model —
    // offered == served + dropped — just like the spatial-only path.
    let duration_s = 20.0;
    let rates = [0.0, 30.0, 0.0, 0.0, 30.0]; // googlenet + vgg
    let ctx1 = SchedCtx::new(1, None);
    let shared = SpaceTimeScheduler::temporal_only()
        .schedule(&ctx1, &rates)
        .expect("googlenet+vgg at 30 req/s time-slice onto one GPU");
    assert!(
        shared.lets.iter().any(|l| l.assignments.len() >= 2),
        "premise: the packed schedule must actually share a let"
    );
    // The swap target is a plain spatial layout of the same load.
    let ctx2 = SchedCtx::new(2, None);
    let spatial = ElasticPartitioning::gpulet()
        .schedule(&ctx2, &rates)
        .expect("two dedicated GPUs trivially hold the load");

    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let cfg = SimConfig::default();
    let streams = poisson_streams(
        &[(ModelId::Googlenet, 30.0), (ModelId::Vgg, 30.0)],
        duration_s,
        11,
    )
    .unwrap();
    let mut eng = ServingEngine::new(&lm, &gt, shared.clone(), duration_s, &cfg);
    eng.attach_source(SourceMux::new(dyn_sources(streams)));
    eng.run_until(8_000_000); // 8 s under the shared duty cycle
    eng.swap_schedule(spatial, SwapMode::Migrate);
    eng.run_until(14_000_000); // 6 s spatial
    eng.swap_schedule(shared, SwapMode::Migrate);
    eng.run_stream(); // rest of the trace + drain, shared again
    eng.close();

    let injected = eng.injected_per_model();
    let mut total_injected = 0u64;
    for m in ModelId::ALL {
        let (served, dropped) = eng
            .report()
            .model(m)
            .map_or((0, 0), |mm| (mm.served, mm.dropped));
        assert_eq!(
            served + dropped,
            injected[m.index()],
            "{m}: served {served} + dropped {dropped} != injected {}",
            injected[m.index()]
        );
        total_injected += injected[m.index()];
    }
    assert!(total_injected > 800, "trace should offer real load: {total_injected}");
    // Both co-tenants must actually be served through the shared let,
    // not silently dropped into a trivially-conserving run.
    for m in [ModelId::Googlenet, ModelId::Vgg] {
        let served = eng.report().model(m).map_or(0, |mm| mm.served);
        assert!(
            served as f64 > 0.8 * duration_s * 30.0,
            "{m}: only {served} served"
        );
    }
}

#[test]
fn node_failure_accounts_every_request_as_lost_dropped_or_served() {
    // The PR 9 failure path through the raw `ServingEngine`: `fail()`
    // mid-trace destroys queued + in-flight work (counted as
    // `lost_to_failure`), arrivals routed to the downed node drop
    // *counted* against the empty schedule, and a `Migrate` swap
    // re-admits the node. The conservation identity becomes
    // `injected == served + dropped + lost_to_failure`, exactly.
    let duration_s = 12.0;
    let rates = [120.0, 0.0, 0.0, 0.0, 40.0]; // lenet + vgg
    let ctx = SchedCtx::new(2, None);
    let schedule = ElasticPartitioning::gpulet()
        .schedule(&ctx, &rates)
        .expect("two GPUs hold lenet+vgg at these rates");

    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let cfg = SimConfig::default();
    let streams = poisson_streams(
        &[(ModelId::Lenet, 120.0), (ModelId::Vgg, 40.0)],
        duration_s,
        13,
    )
    .unwrap();
    let mut eng = ServingEngine::new(&lm, &gt, schedule.clone(), duration_s, &cfg);
    eng.attach_source(SourceMux::new(dyn_sources(streams)));
    eng.run_until(4_000_000); // 4 s of healthy service
    eng.fail(); // the node dies with work queued and in flight
    eng.run_until(7_000_000); // 3 s down: arrivals drop counted
    eng.swap_schedule(schedule, SwapMode::Migrate); // recovery
    eng.run_stream();
    eng.close();

    let injected = eng.injected_per_model();
    let mut total_lost = 0u64;
    let mut total_dropped = 0u64;
    for m in ModelId::ALL {
        let (served, dropped, lost) = eng
            .report()
            .model(m)
            .map_or((0, 0, 0), |mm| (mm.served, mm.dropped, mm.lost_to_failure));
        assert_eq!(
            served + dropped + lost,
            injected[m.index()],
            "{m}: served {served} + dropped {dropped} + lost {lost} != injected {}",
            injected[m.index()]
        );
        total_lost += lost;
        total_dropped += dropped;
    }
    assert!(total_lost > 0, "failing mid-trace must destroy in-progress work");
    assert!(total_dropped > 0, "arrivals during the outage must drop counted");
    // Both models are served again after the Migrate re-admission.
    for m in [ModelId::Lenet, ModelId::Vgg] {
        let served = eng.report().model(m).map_or(0, |mm| mm.served);
        assert!(served > 0, "{m}: nothing served across the failure");
    }
}
