//! Request conservation across the continuous-time adaptive serving
//! path (the fix for the restart-the-world state loss at window
//! boundaries): over a Fig-14 trace with multiple re-organizations,
//! every arrival is served or dropped exactly once — never lost at a
//! window cut or schedule swap, never served twice (the engine's
//! debug-build double-serve guard arms inside these runs) — and the
//! whole path is deterministic given a seed.

use gpulets::coordinator::AdaptiveServer;
use gpulets::experiments::common::paper_ctx;
use gpulets::models::ModelId;
use gpulets::sched::ElasticPartitioning;
use gpulets::workload::FluctuationTrace;

#[test]
fn conservation_across_reorganizations() {
    let ctx = paper_ctx(false);
    let scheduler = ElasticPartitioning::gpulet();
    let server = AdaptiveServer::new(&ctx, &scheduler);
    // 900 s covers wave-1 rise, peak, and fall: partitions both grow
    // and shrink, so queued work crosses several swap boundaries.
    let out = server
        .run_trace(&FluctuationTrace::default(), 900.0, 2024)
        .expect("finite trace rates");

    let reorgs = out.windows.iter().filter(|w| w.reorganized).count();
    assert!(reorgs >= 3, "need >= 3 reorganization boundaries, got {reorgs}");

    // Exact conservation, per model: offered == served + dropped.
    let mut offered_total = 0u64;
    for m in ModelId::ALL {
        let offered = out.offered[m.index()];
        let (served, dropped) = out
            .report
            .model(m)
            .map_or((0, 0), |mm| (mm.served, mm.dropped));
        assert_eq!(
            served + dropped,
            offered,
            "{m}: served {served} + dropped {dropped} != offered {offered}"
        );
        offered_total += offered;
    }
    assert!(offered_total > 10_000, "trace should offer real load");

    // The adaptive run must stay in the paper-plausible violation band
    // (the paper reports 0.14% over the full trace).
    let share = out.overall_violation_share();
    assert!(share < 0.08, "whole-trace violation share {share}");
}

#[test]
fn adaptive_path_deterministic_given_seed() {
    let ctx = paper_ctx(false);
    let scheduler = ElasticPartitioning::gpulet();
    let server = AdaptiveServer::new(&ctx, &scheduler);
    let a = server
        .run_trace(&FluctuationTrace::default(), 300.0, 7)
        .expect("finite trace rates");
    let b = server
        .run_trace(&FluctuationTrace::default(), 300.0, 7)
        .expect("finite trace rates");
    assert_eq!(a.windows, b.windows);
    assert_eq!(a.offered, b.offered);
    assert_eq!(a.report.to_json().to_string(), b.report.to_json().to_string());
    // A different seed must actually change the trace.
    let c = server
        .run_trace(&FluctuationTrace::default(), 300.0, 8)
        .expect("finite trace rates");
    assert_ne!(
        a.report.to_json().to_string(),
        c.report.to_json().to_string()
    );
}
