//! Equivalence: the `ServingEngine` extraction did not change one-shot
//! simulation semantics.
//!
//! `reference_simulate` below is a frozen copy of the pre-extraction
//! monolithic event loop from `coordinator/simserver.rs` (PR 2 state),
//! with exactly one intentional divergence folded in: the deficit
//! routing counters are decremented when a queued request is dropped
//! (the satellite fix that also landed in the engine), so this test
//! isolates the *extraction* — state factoring, epoch tagging, the
//! run_until/finish split — from that accounting change. Every scenario
//! asserts byte-identical JSON reports, including overload runs where
//! drops and multi-route deficit decisions are exercised, and every
//! sharing mode (the MPS modes consume RNG draws, so event order and
//! RNG order are both pinned).

use std::collections::VecDeque;

use gpulets::coordinator::batcher::slo_timeout_us;
use gpulets::coordinator::{simulate, SimConfig};
use gpulets::gpu::gpulet::GpuLetSpec;
use gpulets::gpu::ShareMode;
use gpulets::interference::ground_truth::{GroundTruth, TaskDemand};
use gpulets::metrics::Report;
use gpulets::models::{profile, ModelId};
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::types::{Assignment, LetPlan};
use gpulets::sched::{ElasticPartitioning, SchedCtx, Schedule, Scheduler};
use gpulets::simclock::{ms_to_us, us_to_ms, EventQueue};
use gpulets::util::rng::Pcg32;
use gpulets::workload::{generate_arrivals, Arrival};

#[derive(Clone, Copy, Debug)]
enum Event {
    Arrive(usize),
    Timeout { let_idx: usize, asg_idx: usize, armed_at: u64 },
    Done { let_idx: usize },
}

struct AsgState {
    queue: VecDeque<(u64, u64)>,
    timer_token: u64,
}

struct AsgConst {
    exec_est_us: u64,
    slo_us: u64,
    timeout_us: u64,
    slo_ms: f64,
}

struct LetState {
    asgs: Vec<AsgState>,
    busy: bool,
    next_asg: usize,
    running: Option<(usize, u32)>,
    inflight: Vec<(usize, u64, u64)>,
}

/// Frozen pre-extraction `simulate` (see module docs).
fn reference_simulate(
    lm: &LatencyModel,
    gt: &GroundTruth,
    schedule: &Schedule,
    arrivals: &[Arrival],
    window_s: f64,
    cfg: &SimConfig,
) -> Report {
    let mut report = Report::new(window_s);
    let mut rng = Pcg32::seeded(cfg.seed);

    let mut routes: Vec<Vec<(usize, usize, f64)>> = vec![Vec::new(); 5];
    let mut route_pos: Vec<Vec<usize>> = schedule
        .lets
        .iter()
        .map(|lp| vec![0usize; lp.assignments.len()])
        .collect();
    for (li, lp) in schedule.lets.iter().enumerate() {
        for (ai, a) in lp.assignments.iter().enumerate() {
            routes[a.model.index()].push((li, ai, a.rate));
            route_pos[li][ai] = routes[a.model.index()].len() - 1;
        }
    }
    let mut served: Vec<Vec<f64>> = routes.iter().map(|r| vec![0.0; r.len()]).collect();

    let mut lets: Vec<LetState> = schedule
        .lets
        .iter()
        .map(|lp| LetState {
            asgs: lp
                .assignments
                .iter()
                .map(|_| AsgState { queue: VecDeque::new(), timer_token: 0 })
                .collect(),
            busy: false,
            next_asg: 0,
            running: None,
            inflight: Vec::new(),
        })
        .collect();

    let consts: Vec<Vec<AsgConst>> = schedule
        .lets
        .iter()
        .map(|lp| {
            let p_exec = exec_fraction(cfg.mode, lp.spec.fraction());
            let duty_us: u64 = lp
                .assignments
                .iter()
                .map(|a| ms_to_us(lm.latency_ms(a.model, a.batch, p_exec)))
                .sum();
            lp.assignments
                .iter()
                .map(|a| {
                    let slo_ms = lm.slo_ms(a.model);
                    let slo_us = ms_to_us(slo_ms);
                    AsgConst {
                        exec_est_us: ms_to_us(lm.latency_ms(a.model, a.batch, p_exec)),
                        slo_us,
                        timeout_us: slo_timeout_us(slo_us, duty_us),
                        slo_ms,
                    }
                })
                .collect()
        })
        .collect();

    let num_gpus = schedule.lets.iter().map(|l| l.spec.gpu + 1).max().unwrap_or(0);
    let mut gpu_busy: Vec<bool> = vec![false; num_gpus];
    let mut gpu_waiters: Vec<VecDeque<usize>> = vec![VecDeque::new(); num_gpus];

    let mut q: EventQueue<Event> = EventQueue::new();
    let arr_us: Vec<u64> = arrivals.iter().map(|a| ms_to_us(a.time_ms)).collect();
    for (i, &t) in arr_us.iter().enumerate() {
        q.push_at_us(t, Event::Arrive(i));
    }
    let horizon = arr_us.last().copied().unwrap_or(0) + ms_to_us(cfg.drain_ms);

    while let Some((now, ev)) = q.pop() {
        if now > horizon {
            break;
        }
        match ev {
            Event::Arrive(i) => {
                let a = &arrivals[i];
                let m = a.model;
                let options = &routes[m.index()];
                if options.is_empty() {
                    report.model_mut(m, lm.slo_ms(m)).record_drop();
                    continue;
                }
                let (pos, &(li, ai, w)) = options
                    .iter()
                    .enumerate()
                    .min_by(|(i1, r1), (i2, r2)| {
                        let k1 = served[m.index()][*i1] / r1.2.max(1e-9);
                        let k2 = served[m.index()][*i2] / r2.2.max(1e-9);
                        k1.total_cmp(&k2)
                    })
                    .unwrap();
                let _ = w;
                served[m.index()][pos] += 1.0;
                lets[li].asgs[ai].queue.push_back((a.id, now));
                let b_target = schedule.lets[li].assignments[ai].batch as usize;
                if !lets[li].busy && lets[li].asgs[ai].queue.len() >= b_target {
                    try_start(
                        li, lm, gt, schedule, &consts, &route_pos, &mut served,
                        &mut lets, &mut gpu_busy, &mut gpu_waiters, &mut q, cfg,
                        &mut rng, &mut report,
                    );
                } else if lets[li].asgs[ai].queue.len() == 1 {
                    let token = {
                        let st = &mut lets[li].asgs[ai];
                        st.timer_token += 1;
                        st.timer_token
                    };
                    q.push_after_us(
                        consts[li][ai].timeout_us,
                        Event::Timeout { let_idx: li, asg_idx: ai, armed_at: token },
                    );
                }
            }
            Event::Timeout { let_idx, asg_idx, armed_at } => {
                if lets[let_idx].asgs[asg_idx].timer_token != armed_at {
                    continue;
                }
                if lets[let_idx].asgs[asg_idx].queue.is_empty() {
                    continue;
                }
                if !lets[let_idx].busy {
                    try_start(
                        let_idx, lm, gt, schedule, &consts, &route_pos, &mut served,
                        &mut lets, &mut gpu_busy, &mut gpu_waiters, &mut q, cfg,
                        &mut rng, &mut report,
                    );
                } else {
                    let token = {
                        let st = &mut lets[let_idx].asgs[asg_idx];
                        st.timer_token += 1;
                        st.timer_token
                    };
                    q.push_after_us(500, Event::Timeout { let_idx, asg_idx, armed_at: token });
                }
            }
            Event::Done { let_idx } => {
                let gpu = schedule.lets[let_idx].spec.gpu;
                let inflight = std::mem::take(&mut lets[let_idx].inflight);
                for (ai, _id, arr) in inflight {
                    let c = &consts[let_idx][ai];
                    let m = schedule.lets[let_idx].assignments[ai].model;
                    report.model_mut(m, c.slo_ms).record(us_to_ms(now - arr));
                }
                lets[let_idx].busy = false;
                lets[let_idx].running = None;
                if cfg.mode == ShareMode::TemporalOnly {
                    gpu_busy[gpu] = false;
                    if let Some(waiter) = gpu_waiters[gpu].pop_front() {
                        try_start(
                            waiter, lm, gt, schedule, &consts, &route_pos, &mut served,
                            &mut lets, &mut gpu_busy, &mut gpu_waiters, &mut q, cfg,
                            &mut rng, &mut report,
                        );
                    }
                }
                if !lets[let_idx].busy {
                    try_start(
                        let_idx, lm, gt, schedule, &consts, &route_pos, &mut served,
                        &mut lets, &mut gpu_busy, &mut gpu_waiters, &mut q, cfg,
                        &mut rng, &mut report,
                    );
                }
            }
        }
    }

    for (li, ls) in lets.iter_mut().enumerate() {
        for (ai, st) in ls.asgs.iter_mut().enumerate() {
            let m = schedule.lets[li].assignments[ai].model;
            for _ in st.queue.drain(..) {
                report.model_mut(m, consts[li][ai].slo_ms).record_drop();
            }
        }
        for (ai, _, _) in ls.inflight.drain(..) {
            let m = schedule.lets[li].assignments[ai].model;
            report.model_mut(m, consts[li][ai].slo_ms).record_drop();
        }
    }
    report
}

#[allow(clippy::too_many_arguments)]
fn try_start(
    let_idx: usize,
    lm: &LatencyModel,
    gt: &GroundTruth,
    schedule: &Schedule,
    consts: &[Vec<AsgConst>],
    route_pos: &[Vec<usize>],
    served: &mut [Vec<f64>],
    lets: &mut [LetState],
    gpu_busy: &mut [bool],
    gpu_waiters: &mut [VecDeque<usize>],
    q: &mut EventQueue<Event>,
    cfg: &SimConfig,
    rng: &mut Pcg32,
    report: &mut Report,
) {
    if lets[let_idx].busy {
        return;
    }
    let now = q.now_us();
    let lp = &schedule.lets[let_idx];
    let n_asgs = lp.assignments.len();

    let mut chosen: Option<usize> = None;
    for k in 0..n_asgs {
        let ai = (lets[let_idx].next_asg + k) % n_asgs;
        let asg = &lp.assignments[ai];
        let c = &consts[let_idx][ai];
        let st = &mut lets[let_idx].asgs[ai];
        let before = st.queue.len();
        st.queue.retain(|&(_, arr)| now + c.exec_est_us <= arr + c.slo_us);
        let dropped = before - st.queue.len();
        if dropped > 0 {
            // The satellite routing fix, mirrored here (see module docs):
            // dropped work no longer counts against the route.
            served[asg.model.index()][route_pos[let_idx][ai]] -= dropped as f64;
            for _ in 0..dropped {
                report.model_mut(asg.model, c.slo_ms).record_drop();
            }
        }
        let st = &lets[let_idx].asgs[ai];
        if !st.queue.is_empty() {
            let full = st.queue.len() >= asg.batch as usize;
            let head_arr = st.queue.front().unwrap().1;
            if full || now - head_arr >= c.timeout_us {
                chosen = Some(ai);
                break;
            }
            let token = {
                let st = &mut lets[let_idx].asgs[ai];
                st.timer_token += 1;
                st.timer_token
            };
            q.push_at_us(
                head_arr + c.timeout_us,
                Event::Timeout { let_idx, asg_idx: ai, armed_at: token },
            );
        }
    }
    let Some(ai) = chosen else { return };

    let gpu = lp.spec.gpu;
    if cfg.mode == ShareMode::TemporalOnly {
        if gpu_busy[gpu] {
            if !gpu_waiters[gpu].contains(&let_idx) {
                gpu_waiters[gpu].push_back(let_idx);
            }
            return;
        }
        gpu_busy[gpu] = true;
    }

    let asg = &lp.assignments[ai];
    let b_actual = (lets[let_idx].asgs[ai].queue.len() as u32).min(asg.batch).max(1);
    let mut inflight = Vec::with_capacity(b_actual as usize);
    for _ in 0..b_actual {
        let (id, arr) = lets[let_idx].asgs[ai].queue.pop_front().unwrap();
        inflight.push((ai, id, arr));
    }

    let p_exec = exec_fraction(cfg.mode, lp.spec.fraction());
    let mut exec = lm.latency_ms(asg.model, b_actual, p_exec);

    if cfg.mode != ShareMode::TemporalOnly {
        if let Some((co_idx, co)) = co_resident_running(schedule, lets, let_idx) {
            let co_lp = &schedule.lets[co_idx];
            let (co_ai, co_b) = co;
            let co_asg = &co_lp.assignments[co_ai];
            let my_prof = profile(asg.model);
            let co_prof = profile(co_asg.model);
            let p_me = lp.spec.fraction();
            let p_co = co_lp.spec.fraction();
            let me = TaskDemand {
                model: asg.model,
                batch: b_actual,
                l2: my_prof.l2_util(p_me, b_actual),
                bw: my_prof.bw_util(p_me, b_actual),
            };
            let other = TaskDemand {
                model: co_asg.model,
                batch: co_b,
                l2: co_prof.l2_util(p_co, co_b),
                bw: co_prof.bw_util(p_co, co_b),
            };
            let base = gt.factor(&me, &other) * cfg.mode.contention_amplification();
            let vol = cfg.mode.contention_volatility();
            let factor = (base * (1.0 + rng.normal(0.0, vol))).max(0.0);
            exec *= 1.0 + factor;
        }
    }

    lets[let_idx].busy = true;
    lets[let_idx].running = Some((ai, b_actual));
    lets[let_idx].inflight = inflight;
    lets[let_idx].next_asg = (ai + 1) % n_asgs;
    q.push_after_us(ms_to_us(exec), Event::Done { let_idx });
}

fn exec_fraction(mode: ShareMode, nominal: f64) -> f64 {
    match mode {
        ShareMode::Partitioned => nominal,
        ShareMode::MpsDefault | ShareMode::TemporalOnly => 1.0,
    }
}

fn co_resident_running(
    schedule: &Schedule,
    lets: &[LetState],
    let_idx: usize,
) -> Option<(usize, (usize, u32))> {
    let gpu = schedule.lets[let_idx].spec.gpu;
    schedule
        .lets
        .iter()
        .enumerate()
        .filter(|(i, lp)| *i != let_idx && lp.spec.gpu == gpu)
        .find_map(|(i, _)| lets[i].running.map(|r| (i, r)))
}

// ---- the actual equivalence assertions ---------------------------------

fn assert_equivalent(
    label: &str,
    schedule: &Schedule,
    arrivals: &[Arrival],
    window_s: f64,
    cfg: &SimConfig,
) {
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let new = simulate(&lm, &gt, schedule, arrivals, window_s, cfg);
    let old = reference_simulate(&lm, &gt, schedule, arrivals, window_s, cfg);
    assert_eq!(
        new.to_json().to_string(),
        old.to_json().to_string(),
        "{label}: engine-backed simulate diverged from the frozen reference"
    );
}

fn sched_for(rates: &[f64; 5], gpus: usize) -> Schedule {
    let ctx = SchedCtx::new(gpus, None);
    ElasticPartitioning::gpulet().schedule(&ctx, rates).unwrap()
}

fn trace(rates: &[(ModelId, f64)], duration_s: f64, seed: u64) -> Vec<Arrival> {
    generate_arrivals(rates, duration_s, seed).unwrap()
}

#[test]
fn feasible_multi_gpu_partitioned() {
    let rates = [80.0, 60.0, 40.0, 20.0, 30.0];
    let schedule = sched_for(&rates, 4);
    let arrivals = trace(
        &[
            (ModelId::Lenet, 80.0),
            (ModelId::Googlenet, 60.0),
            (ModelId::Resnet, 40.0),
            (ModelId::SsdMobilenet, 20.0),
            (ModelId::Vgg, 30.0),
        ],
        12.0,
        41,
    );
    assert_equivalent("fig12-like mix", &schedule, &arrivals, 12.0, &SimConfig::default());
}

#[test]
fn overload_with_drops_and_multi_route_deficits() {
    // High LeNet rate forces multiple gpu-lets (multi-route deficit
    // decisions), and 2x offered load exercises hopeless-head drops +
    // the decrement accounting on both sides.
    let rates = [1500.0, 0.0, 0.0, 0.0, 120.0];
    let schedule = sched_for(&rates, 4);
    let arrivals = trace(
        &[(ModelId::Lenet, 3000.0), (ModelId::Vgg, 240.0)],
        8.0,
        42,
    );
    assert_equivalent("overloaded split", &schedule, &arrivals, 8.0, &SimConfig::default());
}

#[test]
fn unscheduled_model_and_empty_trace() {
    let schedule = sched_for(&[50.0, 0.0, 0.0, 0.0, 0.0], 1);
    let arrivals = trace(&[(ModelId::Lenet, 50.0), (ModelId::Vgg, 10.0)], 5.0, 43);
    assert_equivalent("unscheduled vgg", &schedule, &arrivals, 5.0, &SimConfig::default());
    assert_equivalent("empty trace", &schedule, &[], 5.0, &SimConfig::default());
}

#[test]
fn all_sharing_modes_match() {
    // Consolidated hand-built schedule so the MPS modes draw
    // interference noise (RNG order must match) and TemporalOnly
    // exercises the gpu_busy/waiter path.
    let schedule = Schedule {
        lets: vec![
            LetPlan {
                spec: GpuLetSpec { gpu: 0, size_pct: 20 },
                assignments: vec![Assignment {
                    model: ModelId::Lenet,
                    batch: 8,
                    rate: 400.0,
                }],
            },
            LetPlan {
                spec: GpuLetSpec { gpu: 0, size_pct: 80 },
                assignments: vec![Assignment {
                    model: ModelId::Vgg,
                    batch: 16,
                    rate: 150.0,
                }],
            },
        ],
    };
    let arrivals = trace(
        &[(ModelId::Lenet, 400.0), (ModelId::Vgg, 150.0)],
        10.0,
        44,
    );
    for mode in [ShareMode::Partitioned, ShareMode::MpsDefault, ShareMode::TemporalOnly] {
        assert_equivalent(
            mode.name(),
            &schedule,
            &arrivals,
            10.0,
            &SimConfig { mode, ..Default::default() },
        );
    }
}

#[test]
fn seeds_and_drain_variations_match() {
    let rates = [0.0, 0.0, 120.0, 60.0, 0.0];
    let schedule = sched_for(&rates, 4);
    for (seed, drain_ms) in [(7u64, 2_000.0), (1234, 0.0), (99, 500.0)] {
        let arrivals = trace(
            &[(ModelId::Resnet, 140.0), (ModelId::SsdMobilenet, 70.0)],
            6.0,
            seed,
        );
        assert_equivalent(
            &format!("seed {seed} drain {drain_ms}"),
            &schedule,
            &arrivals,
            6.0,
            &SimConfig { seed, drain_ms, ..Default::default() },
        );
    }
}
