//! Lint conformance: the fixture battery for `gpulets lint`
//! (DESIGN.md §11). Each fixture under `tests/fixtures/lint/` contains
//! exactly the violations its name advertises; the tests pin rule,
//! file and line, so a lexer or rule regression shows up as a moved or
//! missing finding rather than a silently weaker gate.
//!
//! Pinned here (tier-1, `cargo test`):
//! * each of the seven rules fires on its bad fixture at the exact
//!   expected `file:line` spans, and respects its path scope;
//! * the clean fixture — strings, doc comments, `self.expect(..)`,
//!   SAFETY'd unsafe, `#[cfg(test)]` regions — produces zero findings
//!   under the strictest path scope;
//! * the allowlist round-trips through `tomlmini`: regenerated text
//!   parses back and suppresses exactly the findings it pinned;
//! * the lint run over this crate's real `src/` tree is clean — the
//!   same self-test CI enforces as a blocking gate.

use std::path::Path;

use gpulets::analysis::{self, lexer, rules, Allowlist, Finding, LintReport};

const BAD_HASH: &str = include_str!("fixtures/lint/bad_hash.rs");
const BAD_SORT: &str = include_str!("fixtures/lint/bad_sort.rs");
const BAD_UNSAFE: &str = include_str!("fixtures/lint/bad_unsafe.rs");
const BAD_UNWRAP: &str = include_str!("fixtures/lint/bad_unwrap.rs");
const BAD_ALLOC: &str = include_str!("fixtures/lint/bad_alloc.rs");
const BAD_WALLCLOCK: &str = include_str!("fixtures/lint/bad_wallclock.rs");
const CLEAN: &str = include_str!("fixtures/lint/clean.rs");
const REG_CONFIG: &str = include_str!("fixtures/lint/registry_config.rs");
const REG_SCHED: &str = include_str!("fixtures/lint/registry_sched.rs");

fn spans(findings: &[Finding]) -> Vec<String> {
    findings.iter().map(Finding::span).collect()
}

fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

#[test]
fn no_hash_iter_fires_in_scoped_dirs_only() {
    let found = analysis::lint_source("src/sched/bad_hash.rs", BAD_HASH);
    assert_eq!(rules_of(&found), ["no-hash-iter", "no-hash-iter"]);
    assert_eq!(spans(&found), ["src/sched/bad_hash.rs:3", "src/sched/bad_hash.rs:6"]);
    // The same text outside the determinism-scoped dirs is legal.
    let outside = analysis::lint_source("src/perfmodel/bad_hash.rs", BAD_HASH);
    assert!(outside.is_empty(), "scope leak: {outside:?}");
}

#[test]
fn total_cmp_sorts_fires_across_multiline_closures() {
    let found = analysis::lint_source("src/perfmodel/bad_sort.rs", BAD_SORT);
    assert_eq!(rules_of(&found), ["total-cmp-sorts", "total-cmp-sorts"]);
    // Line 9's `max_by` closure only reveals `partial_cmp` on line 10 —
    // the paren window must span the call, not just the call line.
    assert_eq!(spans(&found), ["src/perfmodel/bad_sort.rs:5", "src/perfmodel/bad_sort.rs:9"]);
}

#[test]
fn safety_comment_fires_on_bare_unsafe() {
    let found = analysis::lint_source("src/util/bad_unsafe.rs", BAD_UNSAFE);
    assert_eq!(rules_of(&found), ["safety-comment"]);
    assert_eq!(spans(&found), ["src/util/bad_unsafe.rs:4"]);
}

#[test]
fn no_unwrap_in_lib_fires_outside_tests_and_bins() {
    let found = analysis::lint_source("src/util/bad_unwrap.rs", BAD_UNWRAP);
    assert_eq!(rules_of(&found), ["no-unwrap-in-lib"; 3]);
    // Lines 5 (.unwrap()), 9 (.expect) and 13 (panic!); the unwrap in
    // the fixture's #[cfg(test)] module must not appear.
    assert_eq!(
        spans(&found),
        ["src/util/bad_unwrap.rs:5", "src/util/bad_unwrap.rs:9", "src/util/bad_unwrap.rs:13"]
    );
    let in_bin = analysis::lint_source("src/bin/bad_unwrap.rs", BAD_UNWRAP);
    assert!(in_bin.is_empty(), "src/bin/ is out of scope: {in_bin:?}");
}

#[test]
fn no_alloc_region_fires_on_allocating_call() {
    let found = analysis::lint_source("src/fleet/bad_alloc.rs", BAD_ALLOC);
    assert_eq!(rules_of(&found), ["no-alloc-region"]);
    assert_eq!(spans(&found), ["src/fleet/bad_alloc.rs:6"]);
    assert!(found[0].message.contains(".collect()"), "message names the call: {found:?}");
}

#[test]
fn no_wall_clock_fires_outside_benches_and_the_worker_pool() {
    let found = analysis::lint_source("src/telemetry/bad_wallclock.rs", BAD_WALLCLOCK);
    assert_eq!(rules_of(&found), ["no-wall-clock"; 3]);
    // Line 4 (the `use`), line 8 (`Instant::now()`), line 12
    // (`SystemTime::now()`); the doc-comment prose, the `instantaneous`
    // identifier and the `#[cfg(test)]` use must not appear.
    assert_eq!(
        spans(&found),
        [
            "src/telemetry/bad_wallclock.rs:4",
            "src/telemetry/bad_wallclock.rs:8",
            "src/telemetry/bad_wallclock.rs:12"
        ]
    );
    assert!(found[0].message.contains("simclock"), "message points at sim time: {found:?}");
    // Exempt scopes: the worker pool (real OS threads need real time
    // for parking) and anything outside src/ (benches, tests).
    let pool = analysis::lint_source("src/util/par.rs", BAD_WALLCLOCK);
    assert!(pool.is_empty(), "src/util/par.rs is out of scope: {pool:?}");
    let bench = analysis::lint_source("benches/bad_wallclock.rs", BAD_WALLCLOCK);
    assert!(bench.is_empty(), "benches/ is out of scope: {bench:?}");
}

#[test]
fn registry_enrollment_flags_the_missing_variant() {
    let config = lexer::lex(REG_CONFIG);
    let sched = lexer::lex(REG_SCHED);
    let found = rules::check_registry("src/config.rs", &config, &sched);
    assert_eq!(rules_of(&found), ["registry-enrollment"]);
    // Anchored at the `Missing` variant's declaration line.
    assert_eq!(spans(&found), ["src/config.rs:6"]);
    assert!(
        found[0].message.contains("MissingSched::with_window(4)"),
        "message names the unenrolled constructor: {found:?}"
    );
}

#[test]
fn clean_fixture_has_zero_findings_under_strictest_scope() {
    let found = analysis::lint_source("src/sched/clean.rs", CLEAN);
    assert!(found.is_empty(), "false positives: {found:?}");
}

#[test]
fn allowlist_round_trips_through_tomlmini() {
    let found = analysis::lint_source("src/util/bad_unwrap.rs", BAD_UNWRAP);
    assert_eq!(found.len(), 3);
    // Regenerate from scratch: one [allow.01] entry, count 3, TODO reason.
    let text = Allowlist::regenerate(&found, &Allowlist::default());
    let back = Allowlist::parse(&text).expect("regenerated allowlist must parse");
    assert_eq!(back.entries.len(), 1);
    assert_eq!(back.entries[0].rule, "no-unwrap-in-lib");
    assert_eq!(back.entries[0].file, "src/util/bad_unwrap.rs");
    assert_eq!(back.entries[0].count, 3);
    assert_eq!(back.entries[0].reason, "TODO: justify this entry");
    // Applying it suppresses exactly the findings it pinned.
    let mut report = LintReport::default();
    back.apply(found, &mut report);
    assert!(report.clean(), "regenerated allowlist must make the run clean: {report:?}");
    assert_eq!(report.suppressed, 3);
    assert!(report.slack.is_empty() && report.stale.is_empty());
}

#[test]
fn allowlist_ratchet_surfaces_regressions_whole() {
    let found = analysis::lint_source("src/util/bad_unwrap.rs", BAD_UNWRAP);
    let allow = Allowlist::parse(
        "[allow.01]\nrule = \"no-unwrap-in-lib\"\nfile = \"src/util/bad_unwrap.rs\"\n\
         count = 2\nreason = \"two were justified once\"\n",
    )
    .expect("hand-written allowlist must parse");
    let mut report = LintReport::default();
    allow.apply(found, &mut report);
    // 3 found > 2 allowed: every finding surfaces, none hide under the budget.
    assert_eq!(report.findings.len(), 3, "ratchet must surface the whole group");
    assert_eq!(report.suppressed, 0);
    assert!(!report.clean());
}

#[test]
fn the_real_tree_passes_its_own_lint() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analysis::lint_tree(root).expect("lint walk over the real tree");
    assert!(
        report.clean(),
        "the crate must pass its own lint gate:\n{}",
        report.render_human()
    );
    assert!(report.files_scanned > 40, "walk saw {} files", report.files_scanned);
}
