//! Property-based invariants (via `util::proptest_mini`) over the
//! coordinator's core state machines — the DESIGN.md §8 list:
//! (i) no GPU oversubscribed, (ii) only valid sizes, (iii) accepted
//! schedules satisfy the modeled SLO, (iv) split/merge round-trips,
//! (v) batcher cap respected, (vi) routing conserves requests.

use gpulets::coordinator::batcher::{BatchBuilder, Queued};
use gpulets::coordinator::simserver::{simulate, SimConfig};
use gpulets::experiments::common::paper_ctx;
use gpulets::gpu::gpulet::{
    is_valid_size, merges_to_whole, round_up_size, split_of, MAX_LETS_PER_GPU,
};
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{ElasticPartitioning, Scheduler};
use gpulets::util::proptest_mini::{run, Config};
use gpulets::util::rng::Pcg32;
use gpulets::workload::generate_arrivals;

#[derive(Clone, Debug)]
struct RatesCase([f64; 5]);

fn gen_rates(rng: &mut Pcg32) -> RatesCase {
    let mut rates = [0.0; 5];
    for r in rates.iter_mut() {
        if rng.f64() < 0.75 {
            *r = rng.range(0.0, 600.0);
        }
    }
    RatesCase(rates)
}

fn shrink_rates(c: &RatesCase) -> Vec<RatesCase> {
    let mut out = Vec::new();
    for i in 0..5 {
        if c.0[i] > 0.0 {
            let mut zeroed = c.0;
            zeroed[i] = 0.0;
            out.push(RatesCase(zeroed));
            let mut halved = c.0;
            halved[i] /= 2.0;
            out.push(RatesCase(halved));
        }
    }
    out
}

#[test]
fn prop_schedules_respect_structural_and_slo_invariants() {
    let ctx = paper_ctx(true);
    let scheduler = ElasticPartitioning::gpulet_int();
    run(
        Config { cases: 120, seed: 0x5EED, ..Default::default() },
        gen_rates,
        shrink_rates,
        |case| {
            let Ok(schedule) = scheduler.schedule(&ctx, &case.0) else {
                return Ok(()); // rejection is always allowed
            };
            // (i)+(ii): structural validation incl. per-GPU caps.
            schedule
                .validate(&ctx.lm, ctx.num_gpus)
                .map_err(|e| format!("invalid: {e}"))?;
            // Per-GPU: at most MAX_LETS_PER_GPU lets, sizes valid.
            let layout = schedule.layout(ctx.num_gpus).map_err(|e| e.to_string())?;
            for g in 0..layout.num_gpus() {
                let lets = layout.lets_on(g);
                if lets.len() > MAX_LETS_PER_GPU {
                    return Err(format!("gpu {g} has {} lets", lets.len()));
                }
                if lets.iter().any(|&s| !is_valid_size(s)) {
                    return Err(format!("gpu {g} invalid sizes {lets:?}"));
                }
            }
            // (iii): every let's duty cycle honours the (planning) SLOs.
            for lp in &schedule.lets {
                if !lp.feasible(&ctx.lm, 0.0) {
                    return Err(format!(
                        "infeasible let on gpu{} ({}%)",
                        lp.spec.gpu, lp.spec.size_pct
                    ));
                }
            }
            // Coverage: assigned >= offered.
            let assigned = schedule.assigned_rates();
            for m in ModelId::ALL {
                if assigned[m.index()] < case.0[m.index()] - 1e-6 {
                    return Err(format!("{m} under-assigned"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_split_merge_roundtrip() {
    run(
        Config { cases: 200, seed: 0x5117, ..Default::default() },
        |rng| rng.below(120) as u32 + 1,
        |&want| if want > 1 { vec![want / 2, want - 1] } else { vec![] },
        |&want| {
            let rounded = round_up_size(want.min(100));
            if !is_valid_size(rounded) {
                return Err(format!("round_up({want}) = {rounded} invalid"));
            }
            if let Some((a, b)) = split_of(want.min(100)) {
                if !merges_to_whole(a, b) {
                    return Err(format!("split({want}) = ({a},{b}) doesn't re-merge"));
                }
                if a < want.min(100) {
                    return Err(format!("split({want}) ideal half {a} too small"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_never_exceeds_cap_and_preserves_fifo() {
    run(
        Config { cases: 100, seed: 0xBA7C4, ..Default::default() },
        |rng| {
            let cap = rng.below(31) as u32 + 1;
            let n = rng.below(200) + 1;
            let times: Vec<f64> = {
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exp(50.0) * 1000.0;
                        t
                    })
                    .collect()
            };
            (cap, times)
        },
        |_| vec![],
        |(cap, times)| {
            let mut b = BatchBuilder::new(*cap, 25.0);
            let mut seen_ids = Vec::new();
            for (i, &t) in times.iter().enumerate() {
                if let Some(batch) = b.push(Queued { id: i as u64, arrival_ms: t }) {
                    if batch.len() > *cap as usize {
                        return Err(format!("batch {} > cap {cap}", batch.len()));
                    }
                    seen_ids.extend(batch.requests.iter().map(|q| q.id));
                }
            }
            while let Some(batch) = b.flush() {
                if batch.len() > *cap as usize {
                    return Err(format!("flush batch {} > cap {cap}", batch.len()));
                }
                seen_ids.extend(batch.requests.iter().map(|q| q.id));
            }
            if seen_ids.len() != times.len() {
                return Err(format!("lost requests: {}/{}", seen_ids.len(), times.len()));
            }
            if seen_ids.windows(2).any(|w| w[0] >= w[1]) {
                return Err("FIFO order broken".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simulation_conserves_requests() {
    let ctx = paper_ctx(false);
    let scheduler = ElasticPartitioning::gpulet();
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    run(
        Config { cases: 30, seed: 0x51AB, ..Default::default() },
        |rng| {
            let sched_rates = gen_rates(rng).0.map(|r| r * 0.3);
            let offered = gen_rates(rng).0;
            let seed = rng.next_u64();
            (sched_rates, offered, seed)
        },
        |_| vec![],
        |(sched_rates, offered, seed)| {
            let Ok(schedule) = scheduler.schedule(&ctx, sched_rates) else {
                return Ok(());
            };
            let pairs: Vec<(ModelId, f64)> = ModelId::ALL
                .iter()
                .map(|&m| (m, offered[m.index()]))
                .filter(|&(_, r)| r > 0.0)
                .collect();
            if pairs.is_empty() {
                return Ok(());
            }
            let Ok(arrivals) = generate_arrivals(&pairs, 4.0, *seed) else {
                return Err("finite rates must generate".into());
            };
            let report =
                simulate(&lm, &gt, &schedule, &arrivals, 4.0, &SimConfig::default());
            let total: u64 = ModelId::ALL
                .iter()
                .filter_map(|&m| report.model(m))
                .map(|mm| mm.total())
                .sum();
            if total as usize != arrivals.len() {
                return Err(format!(
                    "conservation broken: {total} accounted vs {} offered",
                    arrivals.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_latency_model_monotonicity() {
    let lm = LatencyModel::new();
    run(
        Config { cases: 300, seed: 0x1A7, ..Default::default() },
        |rng| {
            let m = ModelId::from_index(rng.below(5));
            let b = rng.below(32) as u32 + 1;
            let p = rng.range(0.05, 1.0);
            (m, b, p)
        },
        |_| vec![],
        |&(m, b, p)| {
            let l = lm.latency_ms(m, b, p);
            if !(l.is_finite() && l > 0.0) {
                return Err(format!("L({m},{b},{p}) = {l}"));
            }
            // Monotone: more resource never hurts, bigger batch never faster.
            if lm.latency_ms(m, b, (p + 0.1).min(1.0)) > l + 1e-9 {
                return Err(format!("L not monotone in p at ({m},{b},{p})"));
            }
            if b < 32 && lm.latency_ms(m, b + 1, p) < l - 1e-9 {
                return Err(format!("L not monotone in b at ({m},{b},{p})"));
            }
            Ok(())
        },
    );
}
