//! Integration over the REAL runtime path: artifacts -> PJRT compile ->
//! execute -> serve. Requires `make artifacts`; every test skips
//! gracefully (with a loud message) when artifacts are absent so
//! `cargo test` stays green on a fresh checkout.

use gpulets::coordinator::server::RealServer;
use gpulets::models::ModelId;
use gpulets::runtime::{Engine, Manifest, ModelRegistry};
use gpulets::workload::generate_arrivals;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("GPULETS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: {dir}/manifest.json missing — run `make artifacts`");
        None
    }
}

/// Artifacts + a real engine: the execution tests need both (the
/// default build ships a stub `Engine` whose constructor errors).
fn runtime_dir() -> Option<String> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature — no PJRT runtime");
        return None;
    }
    artifacts_dir()
}

#[test]
fn manifest_covers_all_models_and_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    assert_eq!(manifest.models.len(), 5);
    for m in ModelId::ALL {
        let entry = manifest.entry(m).unwrap();
        assert_eq!(entry.artifacts.len(), 6, "{m}: expected 6 batch artifacts");
        for (&b, art) in &entry.artifacts {
            assert!(art.file.exists(), "{m} b={b}: missing {:?}", art.file);
            assert_eq!(art.input_shape[0] as u32, b);
        }
    }
}

#[test]
fn lenet_executes_and_outputs_logits() {
    let Some(dir) = runtime_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let registry = ModelRegistry::load_models(&engine, &dir, &[ModelId::Lenet]).unwrap();
    let entry = registry.manifest.entry(ModelId::Lenet).unwrap();
    let sample_len: usize = entry.input_shape.iter().product();

    let ones = vec![1.0f32; sample_len];
    let out = registry.infer(ModelId::Lenet, &[ones.clone()]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), 10);
    assert!(out[0].iter().all(|x| x.is_finite()));
    assert!(out[0].iter().any(|&x| x != 0.0));

    // Determinism: same input, same output.
    let out2 = registry.infer(ModelId::Lenet, &[ones]).unwrap();
    assert_eq!(out[0], out2[0]);
}

#[test]
fn batch_padding_matches_per_sample_execution() {
    // A batch of 3 (padded up to the b=4 artifact) must produce the
    // same per-sample outputs as three singleton executions — the
    // Python-side batch-consistency test, replayed through Rust+PJRT.
    let Some(dir) = runtime_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let registry = ModelRegistry::load_models(&engine, &dir, &[ModelId::Lenet]).unwrap();
    let entry = registry.manifest.entry(ModelId::Lenet).unwrap();
    let sample_len: usize = entry.input_shape.iter().product();

    let samples: Vec<Vec<f32>> = (0..3)
        .map(|i| (0..sample_len).map(|j| ((i * 37 + j) % 11) as f32 / 11.0).collect())
        .collect();
    let batched = registry.infer(ModelId::Lenet, &samples).unwrap();
    assert_eq!(batched.len(), 3);
    for (i, s) in samples.iter().enumerate() {
        let solo = registry.infer(ModelId::Lenet, &[s.clone()]).unwrap();
        for (a, b) in batched[i].iter().zip(&solo[0]) {
            assert!((a - b).abs() < 1e-4, "sample {i}: batched {a} vs solo {b}");
        }
    }
}

#[test]
fn real_server_serves_a_small_mix() {
    let Some(dir) = runtime_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let registry =
        ModelRegistry::load_models(&engine, &dir, &[ModelId::Lenet, ModelId::Googlenet])
            .unwrap();
    let arrivals = generate_arrivals(
        &[(ModelId::Lenet, 20.0), (ModelId::Googlenet, 4.0)],
        2.0,
        5,
    )
    .unwrap();
    let mut server = RealServer::new(&registry);
    server.batch = [(ModelId::Lenet, 8u32), (ModelId::Googlenet, 2)].into_iter().collect();
    let outcome = server.serve(&arrivals, 2.0).unwrap();
    let served: u64 = [ModelId::Lenet, ModelId::Googlenet]
        .iter()
        .filter_map(|&m| outcome.report.model(m))
        .map(|mm| mm.served)
        .sum();
    assert_eq!(served as usize, arrivals.len(), "all requests must be served");
    assert!(outcome.exec_wall_s > 0.0);
    assert!(outcome.batches.values().sum::<u64>() >= 2);
}

#[test]
fn golden_outputs_match_python_layer2() {
    // THE cross-language numerics check: Rust+PJRT executing the AOT
    // artifact must reproduce the Python/JAX L2 model output on the
    // manifest's fixed golden input — for every model.
    let Some(dir) = runtime_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let registry = ModelRegistry::load(&engine, &dir).unwrap();
    for m in ModelId::ALL {
        let entry = registry.manifest.entry(m).unwrap();
        let Some(golden) = entry.golden.clone() else {
            panic!("{m}: manifest has no golden vector (re-run `make artifacts`)");
        };
        let sample_len: usize = entry.input_shape.iter().product();
        // Reconstruct the deterministic golden input: ((i*31) % 17)/17
        // over the whole (batch, ...) buffer; sample 0 is what golden
        // compares against.
        let art = &entry.artifacts[&golden.batch];
        let flat: Vec<f32> = (0..art.input_len())
            .map(|i| ((i * 31) % 17) as f32 / 17.0)
            .collect();
        let samples: Vec<Vec<f32>> = flat
            .chunks(sample_len)
            .map(|c| c.to_vec())
            .collect();
        let out = registry.infer(m, &samples).unwrap();
        assert_eq!(out[0].len(), golden.output.len(), "{m}: output dim");
        for (i, (got, want)) in out[0].iter().zip(&golden.output).enumerate() {
            assert!(
                (f64::from(*got) - want).abs() < 1e-3 + want.abs() * 1e-3,
                "{m}[{i}]: rust {got} vs python {want}"
            );
        }
    }
}

#[test]
fn artifacts_contain_no_elided_constants() {
    // Regression guard: elided weights (`constant({...})`) parse as
    // zeros on the Rust side and silently destroy the numerics.
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    for entry in manifest.models.values() {
        for art in entry.artifacts.values() {
            let text = std::fs::read_to_string(&art.file).unwrap();
            assert!(
                !text.contains("constant({...})"),
                "{:?} has elided constants — lower with print_large_constants=True",
                art.file
            );
        }
    }
}

#[test]
fn registry_rejects_oversized_batch_and_bad_sample() {
    let Some(dir) = runtime_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let registry = ModelRegistry::load_models(&engine, &dir, &[ModelId::Lenet]).unwrap();
    let entry = registry.manifest.entry(ModelId::Lenet).unwrap();
    let sample_len: usize = entry.input_shape.iter().product();
    // 33 samples exceeds the largest emitted batch (32).
    let too_many: Vec<Vec<f32>> = (0..33).map(|_| vec![0.0; sample_len]).collect();
    assert!(registry.infer(ModelId::Lenet, &too_many).is_err());
    // Wrong per-sample length.
    assert!(registry.infer(ModelId::Lenet, &[vec![0.0; 3]]).is_err());
    // Empty input is a no-op.
    assert!(registry.infer(ModelId::Lenet, &[]).unwrap().is_empty());
}
