//! Fleet-tier conservativeness.
//!
//! The fleet layer must add *scale*, never *drift*: a 1-node fleet is
//! byte-identical (JSON report) to the single-server
//! `simulate_source` on the same mux/seed — router, lockstep windows,
//! and report merging are all pass-throughs at N=1 — and for N ∈
//! {1, 2, 4} fleet-wide conservation (`offered == served + dropped`,
//! exactly, per model) holds including across a mid-trace rebalance
//! (per-node `swap_schedule(…, Migrate)` + router re-target). The
//! lockstep advance now fans the per-node engines out over the
//! `util::par` worker pool, so thread-count invariance is a *proven*
//! property, not a vacuous one: the parallel battery below pins the
//! full fleet outcome byte-identical for threads ∈ {1, 2, 5} at
//! N ∈ {1, 4, 16}, across a mid-trace rebalance.
//!
//! Thread settings are process-global; these tests may race each
//! other's `set_threads` calls benignly — results are thread-count
//! invariant by design, which is exactly what is being asserted.
//!
//! PR 9 extends the battery with *faults*: node death/recovery and the
//! admission gate must preserve both the per-model dealt identity
//! (`offered == served + dropped + lost_to_failure`) and the gate
//! identity (`demand == offered + shed`), and the whole fault timeline
//! must stay byte-identical across worker counts — fault application is
//! serial by construction, so a thread count must never shift *when* a
//! node dies relative to the arrival stream. A `proptest_mini` sweep
//! over randomly generated fault plans pins conservation for arbitrary
//! outage patterns, not just the scripted ones.

use gpulets::coordinator::{simulate_source, SimConfig};
use gpulets::fleet::{AdmissionMode, AdmissionSpec, FleetConfig, FleetEngine, FleetPlanner};
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{ElasticPartitioning, SchedCtx};
use gpulets::simclock::ms_to_us;
use gpulets::util::proptest_mini;
use gpulets::util::rng::Pcg32;
use gpulets::workload::{
    dyn_sources, poisson_streams, DynSourceMux, FaultEvent, FaultKind, FaultPlan, SourceMux,
};

fn mux_for(pairs: &[(ModelId, f64)], duration_s: f64, seed: u64) -> DynSourceMux {
    SourceMux::new(dyn_sources(poisson_streams(pairs, duration_s, seed).unwrap()))
}

fn assert_conserved_per_model(out: &gpulets::fleet::FleetOutcome) {
    let (served, dropped) = out.served_dropped();
    let lost = out.lost_to_failure();
    for m in ModelId::ALL {
        let i = m.index();
        assert_eq!(
            out.offered[i],
            served[i] + dropped[i] + lost[i],
            "{m}: offered {} != served {} + dropped {} + lost {}",
            out.offered[i],
            served[i],
            dropped[i],
            lost[i]
        );
        // Gate identity per model (degrades move accounting across
        // models, so only exact when nothing was degraded).
        if out.degraded == [0u64; 5] {
            assert_eq!(
                out.demand[i],
                out.offered[i] + out.shed[i],
                "{m}: demand {} != offered {} + shed {}",
                out.demand[i],
                out.offered[i],
                out.shed[i]
            );
        }
    }
    assert!(out.conserved(), "FleetOutcome::conserved must agree with the per-model check");
}

/// A 1-node fleet — windowed lockstep, router pass-through, report
/// merge — must reproduce the single-server one-shot byte-for-byte,
/// including the drop accounting for a model the plan does not place
/// (VGG streams in but only LeNet/ResNet are planned).
#[test]
fn one_node_fleet_byte_identical_to_simulate_source() {
    let ctx = SchedCtx::new(2, None);
    let scheduler = ElasticPartitioning::gpulet();
    let planner = FleetPlanner::new(&ctx, &scheduler, 1);
    let rates = [120.0, 0.0, 60.0, 0.0, 0.0];
    let plan = planner.plan(&rates).unwrap();

    let pairs = [
        (ModelId::Lenet, 120.0),
        (ModelId::Resnet, 60.0),
        (ModelId::Vgg, 25.0), // no placement: dropped counted, both paths
    ];
    let duration = 6.0;
    let seed = 17;
    let sim = SimConfig::default();
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();

    let single = simulate_source(
        &lm,
        &gt,
        &plan.schedules[0],
        mux_for(&pairs, duration, seed),
        duration,
        &sim,
    )
    .to_json()
    .to_string();

    let cfg = FleetConfig {
        sim: sim.clone(),
        window_s: 2.0, // three lockstep windows across the trace
        rebalance: false,
        ..Default::default()
    };
    let mut fleet = FleetEngine::new(
        &lm,
        &gt,
        planner,
        plan,
        mux_for(&pairs, duration, seed),
        duration,
        &cfg,
    );
    fleet.run(duration);
    let out = fleet.finish();

    assert_eq!(
        out.per_node[0].to_json().to_string(),
        single,
        "1-node fleet's node report diverged from simulate_source"
    );
    assert_eq!(
        out.report.to_json().to_string(),
        single,
        "merging one node's report must be the identity"
    );
    assert_conserved_per_model(&out);
    assert!(out.unplaced[ModelId::Vgg.index()] > 0, "VGG must stream in unplaced");
    let vgg = out.report.model(ModelId::Vgg).unwrap();
    assert_eq!(vgg.served, 0);
    assert_eq!(vgg.dropped, out.offered[ModelId::Vgg.index()]);
}

/// Conservation across the fleet — exactly, per model — for N ∈
/// {1, 2, 4}, with a deterministic mid-trace rebalance that both
/// migrates backlog (Migrate swap on every node) and gives a
/// previously-unplaced model (GoogLeNet) its first routes.
#[test]
fn fleet_conserves_across_mid_trace_rebalance() {
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let ctx = SchedCtx::new(4, None);
    let scheduler = ElasticPartitioning::gpulet();
    let initial = [300.0, 0.0, 90.0, 0.0, 60.0];
    let retarget = [150.0, 40.0, 80.0, 0.0, 50.0];
    let pairs = [
        (ModelId::Lenet, 300.0),
        (ModelId::Googlenet, 40.0), // unplaced until the rebalance
        (ModelId::Resnet, 90.0),
        (ModelId::Vgg, 60.0),
    ];
    let duration = 6.0;
    let sim = SimConfig::default();

    for nodes in [1usize, 2, 4] {
        let planner = FleetPlanner::new(&ctx, &scheduler, nodes);
        let plan = planner.plan(&initial).unwrap();
        let cfg = FleetConfig { sim: sim.clone(), rebalance: false, ..Default::default() };
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&pairs, duration, 23),
            duration,
            &cfg,
        );
        fleet.run_until(ms_to_us(2_500.0));
        fleet.rebalance(&retarget).unwrap();
        assert_eq!(fleet.rebalances(), 1);
        fleet.run_until(ms_to_us(duration * 1000.0));
        fleet.run_until(ms_to_us(fleet.last_arrival_ms()) + ms_to_us(sim.drain_ms));
        let out = fleet.finish();

        assert_conserved_per_model(&out);
        let goo = out.report.model(ModelId::Googlenet).unwrap();
        assert!(goo.dropped > 0, "n={nodes}: pre-rebalance GoogLeNet must drop counted");
        assert!(goo.served > 0, "n={nodes}: post-rebalance GoogLeNet must be served");
        // The placed models kept flowing through the hand-over.
        for m in [ModelId::Lenet, ModelId::Resnet, ModelId::Vgg] {
            let mm = out.report.model(m).unwrap();
            assert!(mm.served > 0, "n={nodes}: {m} served nothing");
        }
    }
}

/// Routing (and everything downstream of it) is a pure function of the
/// seed: the exact same bytes come out regardless of the worker-pool
/// thread count — the fleet's node advance runs *on* the pool now, so
/// this is the end-to-end `run()` form of the invariance the parallel
/// battery below proves per `run_until` step.
#[test]
fn fleet_reports_are_seed_stable_across_thread_counts() {
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let ctx = SchedCtx::new(4, None);
    let scheduler = ElasticPartitioning::gpulet();
    let rates = [200.0, 0.0, 80.0, 0.0, 40.0];
    let pairs = [
        (ModelId::Lenet, 200.0),
        (ModelId::Resnet, 80.0),
        (ModelId::Vgg, 40.0),
    ];
    let duration = 4.0;

    let run_fleet = || {
        let planner = FleetPlanner::new(&ctx, &scheduler, 3);
        let plan = planner.plan(&rates).unwrap();
        let cfg = FleetConfig { window_s: 1.0, rebalance: true, ..Default::default() };
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&pairs, duration, 41),
            duration,
            &cfg,
        );
        fleet.run(duration);
        let out = fleet.finish();
        let per_node: Vec<String> =
            out.per_node.iter().map(|r| r.to_json().to_string()).collect();
        (out.report.to_json().to_string(), per_node, out.offered, out.rebalances)
    };

    gpulets::util::par::set_threads(1);
    let a = run_fleet();
    gpulets::util::par::set_threads(4);
    let b = run_fleet();
    gpulets::util::par::set_threads(0);
    assert_eq!(a.0, b.0, "fleet report must not depend on thread count");
    assert_eq!(a.1, b.1, "per-node reports must not depend on thread count");
    assert_eq!(a.2, b.2, "routing must not depend on thread count");
    assert_eq!(a.3, b.3, "rebalance history must not depend on thread count");
}

/// The tentpole's hard equivalence bar: the parallel lockstep advance
/// is byte-identical to the serial one. For N ∈ {1, 4, 16} nodes the
/// *entire* fleet outcome — merged report JSON, every per-node report
/// JSON, routing totals, unplaced counts, rebalance history, event
/// counts, and both peak-footprint metrics — must be bit-equal across
/// worker counts {1, 2, 5}, with a mid-trace rebalance exercising the
/// swap/retarget path under every setting.
#[test]
fn parallel_advance_is_byte_identical_across_thread_counts() {
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let ctx = SchedCtx::new(4, None);
    let scheduler = ElasticPartitioning::gpulet();
    let initial = [300.0, 0.0, 90.0, 0.0, 60.0];
    let retarget = [150.0, 40.0, 80.0, 0.0, 50.0];
    let pairs = [
        (ModelId::Lenet, 300.0),
        (ModelId::Googlenet, 40.0), // unplaced until the rebalance —
        // dealt uniformly, so every node sees arrivals even at N=16
        (ModelId::Resnet, 90.0),
        (ModelId::Vgg, 60.0),
    ];
    let duration = 6.0;
    let sim = SimConfig::default();

    for nodes in [1usize, 4, 16] {
        let outcome_bytes = |threads: usize| {
            gpulets::util::par::set_threads(threads);
            let planner = FleetPlanner::new(&ctx, &scheduler, nodes);
            let plan = planner.plan(&initial).unwrap();
            let cfg =
                FleetConfig { sim: sim.clone(), rebalance: false, ..Default::default() };
            let mut fleet = FleetEngine::new(
                &lm,
                &gt,
                planner,
                plan,
                mux_for(&pairs, duration, 23),
                duration,
                &cfg,
            );
            fleet.run_until(ms_to_us(2_500.0));
            fleet.rebalance(&retarget).unwrap();
            fleet.run_until(ms_to_us(duration * 1000.0));
            fleet.run_until(ms_to_us(fleet.last_arrival_ms()) + ms_to_us(sim.drain_ms));
            let out = fleet.finish();
            assert_conserved_per_model(&out);
            let mut s = out.report.to_json().to_string();
            for r in &out.per_node {
                s.push('\n');
                s.push_str(&r.to_json().to_string());
            }
            s.push_str(&format!(
                "\n{:?} {:?} {} {} {} {}",
                out.offered,
                out.unplaced,
                out.rebalances,
                out.events_processed,
                out.peak_live_events,
                out.peak_routed,
            ));
            s
        };
        let serial = outcome_bytes(1);
        for threads in [2usize, 5] {
            let parallel = outcome_bytes(threads);
            assert_eq!(
                serial, parallel,
                "n={nodes}: outcome diverged between 1 and {threads} workers"
            );
        }
    }
    gpulets::util::par::set_threads(0);
}

/// The PR 9 fault battery: a scripted down→up outage plus an armed shed
/// gate must (a) conserve exactly under the extended identities, (b)
/// actually lose work to the failure and serve again after recovery,
/// and (c) remain *byte-identical* across worker counts {1, 2, 5} —
/// fault application and gate decisions are serial, so the entire
/// timeline (who died when, what was lost, what was shed) is a pure
/// function of the seed and the fault plan.
#[test]
fn fault_timeline_is_byte_identical_across_thread_counts() {
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let ctx = SchedCtx::new(4, None);
    let scheduler = ElasticPartitioning::gpulet();
    let rates = [300.0, 0.0, 90.0, 0.0, 60.0];
    let pairs = [
        (ModelId::Lenet, 300.0),
        (ModelId::Resnet, 90.0),
        (ModelId::Vgg, 60.0),
    ];
    let duration = 6.0;
    let faults = FaultPlan::new(vec![
        FaultEvent { at_s: 2.0, node: 1, kind: FaultKind::Down },
        FaultEvent { at_s: 4.0, node: 1, kind: FaultKind::Up },
    ])
    .unwrap();

    let outcome_bytes = |threads: usize| {
        gpulets::util::par::set_threads(threads);
        let planner = FleetPlanner::new(&ctx, &scheduler, 4);
        let plan = planner.plan(&rates).unwrap();
        let cfg = FleetConfig { window_s: 1.0, rebalance: true, ..Default::default() };
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&pairs, duration, 23),
            duration,
            &cfg,
        );
        fleet.set_fault_plan(faults.clone()).unwrap();
        fleet.set_admission(AdmissionSpec {
            mode: AdmissionMode::Shed,
            ..AdmissionSpec::default()
        });
        fleet.run(duration);
        let out = fleet.finish();
        assert_conserved_per_model(&out);
        assert!(
            out.lost_to_failure().iter().sum::<u64>() > 0,
            "the outage must destroy queued/in-flight work"
        );
        assert_eq!(out.degraded, [0u64; 5], "shed mode never degrades");
        // Node 1 served again after recovery: its whole-run report
        // includes post-recovery service, so it served *something*
        // despite losing its backlog at t=2 s.
        let node1_served: u64 =
            out.per_node[1].models().map(|(_, mm)| mm.served).sum();
        assert!(node1_served > 0, "recovered node must serve again");
        let mut s = out.report.to_json().to_string();
        for r in &out.per_node {
            s.push('\n');
            s.push_str(&r.to_json().to_string());
        }
        s.push_str(&format!(
            "\n{:?} {:?} {:?} {:?} {} {} {}",
            out.demand,
            out.offered,
            out.shed,
            out.lost_to_failure(),
            out.rebalances,
            out.replan_failures,
            out.events_processed,
        ));
        s
    };
    let serial = outcome_bytes(1);
    for threads in [2usize, 5] {
        let parallel = outcome_bytes(threads);
        assert_eq!(
            serial, parallel,
            "fault timeline diverged between 1 and {threads} workers"
        );
    }
    gpulets::util::par::set_threads(0);
}

/// Conservation is not a property of *nice* fault scripts: randomly
/// generated plans (arbitrary outage counts, overlaps resolved by the
/// generator, nodes that never recover) must keep the ledger exact.
#[test]
fn prop_random_fault_plans_conserve() {
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let ctx = SchedCtx::new(2, None);
    let scheduler = ElasticPartitioning::gpulet();
    let rates = [150.0, 0.0, 45.0, 0.0, 30.0];
    let pairs = [
        (ModelId::Lenet, 150.0),
        (ModelId::Resnet, 45.0),
        (ModelId::Vgg, 30.0),
    ];
    let duration = 3.0;
    let nodes = 3usize;

    #[derive(Clone, Debug)]
    struct Case {
        fault_seed: u64,
        episodes: usize,
    }
    let gen = |rng: &mut Pcg32| Case {
        fault_seed: rng.next_u64(),
        episodes: 1 + rng.below(4),
    };
    let shrink = |c: &Case| {
        if c.episodes > 1 {
            vec![Case { fault_seed: c.fault_seed, episodes: c.episodes - 1 }]
        } else {
            Vec::new()
        }
    };
    proptest_mini::run(
        proptest_mini::Config { cases: 10, seed: 0xFA17, ..Default::default() },
        gen,
        shrink,
        |case| {
            let faults =
                FaultPlan::generate(case.fault_seed, nodes, duration, case.episodes)
                    .map_err(|e| e.to_string())?;
            let planner = FleetPlanner::new(&ctx, &scheduler, nodes);
            let plan = planner.plan(&rates).map_err(|e| e.to_string())?;
            let cfg = FleetConfig { window_s: 0.5, rebalance: true, ..Default::default() };
            let mut fleet = FleetEngine::new(
                &lm,
                &gt,
                planner,
                plan,
                mux_for(&pairs, duration, 31),
                duration,
                &cfg,
            );
            fleet.set_fault_plan(faults).map_err(|e| e.to_string())?;
            fleet.run(duration);
            let out = fleet.finish();
            let (served, dropped) = out.served_dropped();
            let lost = out.lost_to_failure();
            for m in ModelId::ALL {
                let i = m.index();
                if out.offered[i] != served[i] + dropped[i] + lost[i] {
                    return Err(format!(
                        "{m}: offered {} != served {} + dropped {} + lost {}",
                        out.offered[i], served[i], dropped[i], lost[i]
                    ));
                }
                if out.demand[i] != out.offered[i] + out.shed[i] {
                    return Err(format!(
                        "{m}: demand {} != offered {} + shed {}",
                        out.demand[i], out.offered[i], out.shed[i]
                    ));
                }
            }
            if !out.conserved() {
                return Err("FleetOutcome::conserved() == false".into());
            }
            Ok(())
        },
    );
}
