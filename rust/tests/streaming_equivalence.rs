//! Streaming vs materialized serving equivalence.
//!
//! PR 4 moved arrivals out of the event heap: the engine pulls from a
//! k-way [`SourceMux`] (one pending arrival per stream) and keeps one
//! duty-timer slot per assignment, so its live event set is O(#streams
//! + #assignments + #gpu-lets) instead of O(trace). These tests pin the
//! refactor's contract: for every sharing mode, under overload, across
//! live schedule swaps (both backlog policies), and under split-inject
//! / `run_until` stepping, the streamed path produces **byte-identical
//! JSON reports** to the legacy bulk-inject path — and the streamed
//! Fig-14 trace's peak live-event count stays within the structural
//! bound regardless of trace length.

use gpulets::coordinator::{simulate, simulate_source, ServingEngine, SimConfig, SwapMode};
use gpulets::gpu::ShareMode;
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{ElasticPartitioning, SchedCtx, Schedule, Scheduler};
use gpulets::simclock::{ms_to_us, SimTimeUs};
use gpulets::workload::{
    dyn_sources, generate_arrivals, poisson_streams, varying_streams, Arrival,
    DynSourceMux, FluctuationTrace, SourceMux,
};

fn world() -> (LatencyModel, GroundTruth) {
    (LatencyModel::new(), GroundTruth::default())
}

fn sched_for(rates: &[f64; 5], gpus: usize) -> Schedule {
    let ctx = SchedCtx::new(gpus, None);
    ElasticPartitioning::gpulet().schedule(&ctx, rates).unwrap()
}

fn horizon_us(arrivals: &[Arrival], cfg: &SimConfig) -> SimTimeUs {
    arrivals.last().map(|a| ms_to_us(a.time_ms)).unwrap_or(0) + ms_to_us(cfg.drain_ms)
}

fn poisson_mux(pairs: &[(ModelId, f64)], duration_s: f64, seed: u64) -> DynSourceMux {
    SourceMux::new(dyn_sources(poisson_streams(pairs, duration_s, seed).unwrap()))
}

/// Legacy path: bulk-inject the whole trace into the heap, run to the
/// drain horizon, finish.
fn bulk_report(
    schedule: &Schedule,
    arrivals: &[Arrival],
    window_s: f64,
    cfg: &SimConfig,
) -> String {
    let (lm, gt) = world();
    let mut eng = ServingEngine::new(&lm, &gt, schedule.clone(), window_s, cfg);
    eng.inject(arrivals);
    eng.run_until(horizon_us(arrivals, cfg));
    eng.finish().to_json().to_string()
}

/// Assert the three serving paths agree byte-for-byte on one scenario:
/// bulk inject, the streamed materialized-trace adapter (`simulate`),
/// and pure per-model Poisson streams (`simulate_source`).
fn assert_three_way(
    label: &str,
    schedule: &Schedule,
    pairs: &[(ModelId, f64)],
    duration_s: f64,
    seed: u64,
    cfg: &SimConfig,
) {
    let (lm, gt) = world();
    let arrivals = generate_arrivals(pairs, duration_s, seed).unwrap();
    let bulk = bulk_report(schedule, &arrivals, duration_s, cfg);
    let via_trace =
        simulate(&lm, &gt, schedule, &arrivals, duration_s, cfg).to_json().to_string();
    let via_streams = simulate_source(
        &lm,
        &gt,
        schedule,
        poisson_mux(pairs, duration_s, seed),
        duration_s,
        cfg,
    )
    .to_json()
    .to_string();
    assert_eq!(bulk, via_trace, "{label}: simulate() diverged from bulk inject");
    assert_eq!(bulk, via_streams, "{label}: streamed sources diverged from bulk inject");
}

#[test]
fn all_sharing_modes_byte_identical() {
    let rates = [120.0, 0.0, 60.0, 0.0, 40.0];
    let schedule = sched_for(&rates, 2);
    let pairs = [
        (ModelId::Lenet, 120.0),
        (ModelId::Resnet, 60.0),
        (ModelId::Vgg, 40.0),
    ];
    for mode in [ShareMode::Partitioned, ShareMode::MpsDefault, ShareMode::TemporalOnly] {
        let cfg = SimConfig { mode, ..Default::default() };
        // MPS modes consume RNG draws on interference, so this also
        // pins that event order (and therefore RNG order) is identical.
        assert_three_way(mode.name(), &schedule, &pairs, 8.0, 41, &cfg);
    }
}

#[test]
fn overload_with_drops_byte_identical() {
    // Scheduled for 50 req/s VGG, offered 10x: hopeless-head drops and
    // deficit-counter decrements all fire on both paths.
    let schedule = sched_for(&[0.0, 0.0, 0.0, 0.0, 50.0], 1);
    let pairs = [(ModelId::Vgg, 500.0)];
    assert_three_way("overload", &schedule, &pairs, 6.0, 7, &SimConfig::default());
}

#[test]
fn multi_seed_sweep_byte_identical() {
    let rates = [80.0, 40.0, 0.0, 0.0, 30.0];
    let schedule = sched_for(&rates, 2);
    let pairs = [
        (ModelId::Lenet, 80.0),
        (ModelId::Googlenet, 40.0),
        (ModelId::Vgg, 30.0),
    ];
    for seed in [1u64, 99, 2024] {
        assert_three_way(
            &format!("seed {seed}"),
            &schedule,
            &pairs,
            5.0,
            seed,
            &SimConfig::default(),
        );
    }
}

/// Swap-mid-trace: a live schedule hand-over at 2 s (and again at 4 s)
/// while work is queued and in flight must be byte-identical between
/// the bulk and streamed paths, for both backlog policies.
#[test]
fn swap_mid_trace_byte_identical() {
    let (lm, gt) = world();
    let cfg = SimConfig::default();
    let vgg = sched_for(&[0.0, 0.0, 0.0, 0.0, 60.0], 1);
    let lenet_vgg = sched_for(&[80.0, 0.0, 0.0, 0.0, 40.0], 2);
    let pairs = [(ModelId::Lenet, 80.0), (ModelId::Vgg, 90.0)];
    let duration = 6.0;
    let seed = 17;
    let arrivals = generate_arrivals(&pairs, duration, seed).unwrap();
    let horizon = horizon_us(&arrivals, &cfg);

    for mode in [SwapMode::Migrate, SwapMode::DropQueued] {
        let mut bulk = ServingEngine::new(&lm, &gt, vgg.clone(), duration, &cfg);
        bulk.inject(&arrivals);
        bulk.run_until(ms_to_us(2_000.0));
        bulk.swap_schedule(lenet_vgg.clone(), mode);
        bulk.run_until(ms_to_us(4_000.0));
        bulk.swap_schedule(vgg.clone(), mode);
        bulk.run_until(horizon);
        let r_bulk = bulk.finish().to_json().to_string();

        let mut streamed = ServingEngine::new(&lm, &gt, vgg.clone(), duration, &cfg);
        streamed.attach_source(poisson_mux(&pairs, duration, seed));
        streamed.run_until(ms_to_us(2_000.0));
        streamed.swap_schedule(lenet_vgg.clone(), mode);
        streamed.run_until(ms_to_us(4_000.0));
        streamed.swap_schedule(vgg.clone(), mode);
        streamed.run_until(horizon);
        let r_streamed = streamed.finish().to_json().to_string();

        assert_eq!(r_bulk, r_streamed, "{mode:?}: swap-mid-trace diverged");
    }
}

/// Split-inject + 250 ms `run_until` stepping on the bulk side vs a
/// single streamed pass: identical reports (the window-stepped adaptive
/// server leans on exactly this).
#[test]
fn stepped_run_until_byte_identical() {
    let (lm, gt) = world();
    let cfg = SimConfig::default();
    let rates = [60.0, 0.0, 0.0, 0.0, 30.0];
    let schedule = sched_for(&rates, 2);
    let pairs = [(ModelId::Lenet, 60.0), (ModelId::Vgg, 30.0)];
    let duration = 6.0;
    let seed = 13;
    let arrivals = generate_arrivals(&pairs, duration, seed).unwrap();
    let horizon = horizon_us(&arrivals, &cfg);

    let mut stepped = ServingEngine::new(&lm, &gt, schedule.clone(), duration, &cfg);
    let (a, b) = arrivals.split_at(arrivals.len() / 2);
    stepped.inject(a);
    stepped.inject(b);
    let mut t = 0;
    while t < horizon {
        t = (t + 250_000).min(horizon);
        stepped.run_until(t);
    }
    let r_stepped = stepped.finish().to_json().to_string();

    // Streamed engine, stepped with the same boundaries.
    let mut streamed = ServingEngine::new(&lm, &gt, schedule.clone(), duration, &cfg);
    streamed.attach_source(poisson_mux(&pairs, duration, seed));
    let mut t = 0;
    while t < horizon {
        t = (t + 250_000).min(horizon);
        streamed.run_until(t);
    }
    let r_streamed = streamed.finish().to_json().to_string();
    assert_eq!(r_stepped, r_streamed, "stepped streaming diverged from split-inject");
}

/// The adaptive (Fig 14) path: the materialized-trace adapter and the
/// streamed inhomogeneous sources must produce identical windows,
/// offered counts, and whole-trace reports.
#[test]
fn adaptive_run_source_matches_run_arrivals() {
    use gpulets::coordinator::AdaptiveServer;
    use gpulets::workload::generate_varying;

    let ctx = SchedCtx::new(4, None);
    let sched = ElasticPartitioning::gpulet();
    let srv = AdaptiveServer::new(&ctx, &sched);
    let trace = FluctuationTrace::default();
    let duration = 250.0;
    let seed = 11;

    // Streamed end-to-end (what run_trace does now).
    let streamed = srv.run_trace(&trace, duration, seed).unwrap();

    // Materialized adapter over the identical trace.
    let arrivals = generate_varying(
        &ModelId::ALL,
        |m, t| trace.rate_at(m, t),
        duration,
        1.0,
        seed,
    )
    .unwrap();
    let materialized = srv.run_arrivals(&arrivals, duration);

    assert_eq!(streamed.windows, materialized.windows);
    assert_eq!(streamed.offered, materialized.offered);
    assert_eq!(
        streamed.report.to_json().to_string(),
        materialized.report.to_json().to_string()
    );
}

/// The streamed Fig-14 trace keeps the live event set within the
/// structural O(active) bound — heap `Done`s (one per busy gpu-let,
/// and gpu-lets are at most two per GPU) + one duty-timer slot per
/// assignment + one pending arrival per stream — no matter how long
/// the trace runs.
#[test]
fn streamed_fig14_peak_events_bounded_and_trace_length_free() {
    let (lm, gt) = world();
    let cfg = SimConfig::default();
    let trace = FluctuationTrace::default();
    // A fixed mid-size schedule; the wave's peaks overload it, which
    // only stresses the bound harder (request queues absorb the
    // backlog — the live *event* set must stay structural).
    let schedule = sched_for(&[50.0; 5], 4);
    let n_lets = schedule.lets.len();
    let total_asgs: usize = schedule.lets.iter().map(|l| l.assignments.len()).sum();
    let num_gpus = 4;

    let mut peaks = Vec::new();
    for duration in [100.0, 1_000.0] {
        let tr = trace.clone();
        let streams = varying_streams(
            &ModelId::ALL,
            move |m, t| tr.rate_at(m, t),
            duration,
            1.0,
            2024,
        )
        .unwrap();
        let n_streams = streams.len();
        let mut eng = ServingEngine::new(&lm, &gt, schedule.clone(), duration, &cfg);
        eng.attach_source(SourceMux::new(dyn_sources(streams)));
        eng.run_stream();
        eng.close();
        let offered: u64 = eng.injected_per_model().iter().sum();
        assert!(offered > 5_000, "duration {duration}: load too small ({offered})");

        let peak = eng.peak_live_events();
        let bound = n_streams + total_asgs + n_lets;
        assert!(
            peak <= bound,
            "duration {duration}: peak {peak} > structural bound {bound} \
             (streams {n_streams} + assignments {total_asgs} + lets {n_lets})"
        );
        // gpu-lets are at most two per physical GPU, so the bound is
        // also <= streams + assignments + 2 * #GPUs.
        assert!(peak <= n_streams + total_asgs + 2 * num_gpus);
        peaks.push(peak);
    }
    // 10x the trace: the peak must NOT scale with trace length (the
    // bulk path's peak would be ~the arrival count).
    assert!(
        peaks[1] <= peaks[0].max(1) * 2,
        "peak grew with trace length: {peaks:?}"
    );
}
