//! Property tests for the space-time scheduler (`util::proptest_mini`):
//! every temporally-shared schedule the packing pass emits keeps the
//! interference-inflated per-let duty-sum utilization <= 1.0 and arms
//! per-model timeout constants at least as large as the model's own
//! (solo) duty — and hand-built mutant schedules that break the
//! duty-sum bound are rejected by `Schedule::validate`.

use gpulets::experiments::common::fitted_interference;
use gpulets::models::ModelId;
use gpulets::gpu::gpulet::GpuLetSpec;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{Assignment, LetPlan, SchedCtx, Schedule, Scheduler, SpaceTimeScheduler};
use gpulets::util::proptest_mini::{run, Config};
use gpulets::util::rng::Pcg32;

/// One generated case: a context choice and an offered rate vector.
type Case = (usize, [f64; 5]);

fn contexts() -> Vec<SchedCtx> {
    let mut out = Vec::new();
    for gpus in [1usize, 2, 4] {
        out.push(SchedCtx::new(gpus, None));
        out.push(SchedCtx::new(gpus, Some(fitted_interference())));
    }
    out
}

fn gen_case(rng: &mut Pcg32) -> Case {
    let ctx_idx = rng.below(6);
    let mut rates = [0.0; 5];
    for r in rates.iter_mut() {
        if rng.f64() < 0.7 {
            *r = rng.range(0.0, 300.0);
        }
    }
    (ctx_idx, rates)
}

fn shrink_case(case: &Case) -> Vec<Case> {
    let (ctx_idx, rates) = case;
    let mut out = Vec::new();
    for i in 0..5 {
        if rates[i] > 0.0 {
            let mut z = *rates;
            z[i] = 0.0;
            out.push((*ctx_idx, z));
            let mut h = *rates;
            h[i] /= 2.0;
            out.push((*ctx_idx, h));
        }
    }
    out
}

/// Worst predicted interference of `lets[i]` against its co-resident
/// lets — the same victim-first, index-excluded convention the
/// scheduler's own feasibility pass uses.
fn worst_intf(ctx: &SchedCtx, lets: &[LetPlan], i: usize) -> f64 {
    let me = &lets[i];
    lets.iter()
        .enumerate()
        .filter(|(j, lp)| *j != i && lp.spec.gpu == me.spec.gpu)
        .map(|(_, lp)| ctx.predicted_intf(me, lp))
        .fold(0.0, f64::max)
}

/// The two space-time invariants on one emitted schedule.
fn check_spacetime_bounds(ctx: &SchedCtx, s: &Schedule) -> Result<(), String> {
    for i in 0..s.lets.len() {
        let lp = &s.lets[i];
        let intf = worst_intf(ctx, &s.lets, i);
        let util = lp.utilization(&ctx.lm, intf);
        if util > 1.0 + 1e-6 {
            return Err(format!(
                "gpu{} let {}%: inflated duty-sum utilization {util:.4} > 1.0",
                lp.spec.gpu, lp.spec.size_pct
            ));
        }
        if lp.assignments.len() < 2 {
            continue;
        }
        // Timeout constant >= solo duty: the planned `slo_timeout_us`
        // is SLO − 1.25·D, so SLO >= 1.25·D + E_i must hold for every
        // co-tenant even under the planning (tightened) SLOs.
        let d = lp.duty_cycle_ms(&ctx.lm, intf);
        let p = lp.spec.fraction();
        for a in &lp.assignments {
            let e = ctx.lm.latency_ms(a.model, a.batch, p) * (1.0 + intf);
            if ctx.lm.slo_ms(a.model) + 1e-6 < 1.25 * d + e {
                return Err(format!(
                    "gpu{} let {}%: {} timeout slack broken (slo {} < 1.25*{d} + {e})",
                    lp.spec.gpu,
                    lp.spec.size_pct,
                    a.model,
                    ctx.lm.slo_ms(a.model)
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn emitted_temporal_schedules_hold_duty_sum_and_timeout_slack() {
    let ctxs = contexts();
    let spatial = SpaceTimeScheduler::spatial_only();
    let temporal = SpaceTimeScheduler::temporal_only();
    let combined = SpaceTimeScheduler::combined();
    run(
        Config { cases: 48, seed: 0x5ACE, ..Default::default() },
        gen_case,
        shrink_case,
        |&(ctx_idx, rates)| {
            let ctx = &ctxs[ctx_idx];
            // temporal-only always runs the packing pass; combined runs
            // it exactly when spatial splitting alone rejects the load
            // (otherwise it returns elastic's schedule, whose invariants
            // `Schedule::validate` already pins at interference 0).
            let mut emitted = Vec::new();
            if let Ok(s) = temporal.schedule(ctx, &rates) {
                emitted.push(s);
            }
            if spatial.schedule(ctx, &rates).is_err() {
                if let Ok(s) = combined.schedule(ctx, &rates) {
                    emitted.push(s);
                }
            }
            for s in &emitted {
                check_spacetime_bounds(ctx, s)?;
            }
            Ok(())
        },
    );
}

#[test]
fn mutant_schedules_breaking_the_duty_sum_bound_are_rejected() {
    let lm = LatencyModel::new();

    // Solo mutant: one assignment demanding twice the let's wall-clock.
    let e = lm.latency_ms(ModelId::Lenet, 1, 1.0);
    let solo = Schedule {
        lets: vec![LetPlan {
            spec: GpuLetSpec { gpu: 0, size_pct: 100 },
            assignments: vec![Assignment {
                model: ModelId::Lenet,
                batch: 1,
                rate: 2.0 * 1000.0 / e,
            }],
        }],
    };
    let err = solo.validate(&lm, 1).unwrap_err().to_string();
    assert!(err.contains("duty-sum utilization"), "unexpected error: {err}");

    // Time-sliced mutant: two co-tenants whose demanded duty fractions
    // sum to ~1.6 of the let's wall-clock.
    let e_g = lm.latency_ms(ModelId::Googlenet, 4, 1.0);
    let e_v = lm.latency_ms(ModelId::Vgg, 1, 1.0);
    let shared = Schedule {
        lets: vec![LetPlan {
            spec: GpuLetSpec { gpu: 0, size_pct: 100 },
            assignments: vec![
                Assignment { model: ModelId::Googlenet, batch: 4, rate: 0.8 * 4000.0 / e_g },
                Assignment { model: ModelId::Vgg, batch: 1, rate: 0.8 * 1000.0 / e_v },
            ],
        }],
    };
    let err = shared.validate(&lm, 1).unwrap_err().to_string();
    assert!(err.contains("duty-sum utilization"), "unexpected error: {err}");
}
