//! Fixture: allocation inside a `lint: no-alloc` region is flagged
//! (expected finding: line 6, the `.collect()` call; the unclosed /
//! nested marker diagnostics are pinned by the rules unit tests).
pub fn hot(xs: &[u64]) -> u64 {
    // lint: no-alloc
    let doubled: Vec<u64> = xs.iter().map(|x| x * 2).collect();
    let total: u64 = doubled.iter().sum();
    // lint: end-no-alloc
    total
}
