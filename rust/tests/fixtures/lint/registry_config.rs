//! Fixture config: `Algo` with one enrolled and one missing variant
//! (expected finding: line 6, `Missing` not enrolled in registry()).

pub enum Algo {
    Enrolled,
    Missing,
}

impl Algo {
    pub fn scheduler(self) -> Box<dyn Send> {
        match self {
            Algo::Enrolled => Box::new(EnrolledSched::new()),
            Algo::Missing => Box::new(MissingSched::with_window(4)),
        }
    }
}
