//! Fixture: near-miss constructs that must NOT trigger any rule, even
//! when lexed under the strictest path scope (`src/sched/...`).
//!
//! Mentions HashMap in a doc comment only, and prose about the
//! `// lint: no-alloc` marker is not a directive.

pub fn strings_are_blanked() -> &'static str {
    "use std::collections::HashMap and panic!(now) and x.unwrap()"
}

pub fn sort_total(v: &mut [f64]) {
    v.sort_by(|a, b| a.total_cmp(b));
}

pub struct Parser {
    pos: usize,
}

impl Parser {
    /// `self.expect(..)` is a parser method, not `Option::expect`.
    pub fn expect(&mut self, b: u8) -> bool {
        self.pos += 1;
        b == 0
    }

    pub fn run(&mut self) -> bool {
        self.expect(b'{')
    }
}

// SAFETY: the pointer is valid for reads by the caller contract.
pub unsafe fn read(p: *const u32) -> u32 {
    // SAFETY: forwarded from the caller contract above.
    unsafe { *p }
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(Some(1).unwrap(), 1);
    }
}
