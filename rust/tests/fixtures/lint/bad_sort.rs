//! Fixture: float orderings routed through `partial_cmp` are flagged
//! (expected findings: lines 5 and 9; line 9 needs the multi-line
//! paren window to see the closure body on line 10).
pub fn sort_desc(v: &mut [f64]) {
    v.sort_by(|a, b| b.partial_cmp(a).unwrap_or(core::cmp::Ordering::Equal));
}

pub fn best(v: &[f64]) -> Option<f64> {
    v.iter().copied().max_by(|a, b| {
        a.partial_cmp(b).unwrap_or(core::cmp::Ordering::Equal)
    })
}
