//! Fixture sched registry: only `EnrolledSched` is enrolled, so the
//! `Algo::Missing` constructor from the config fixture has no entry.

pub fn registry() -> Vec<Box<dyn Send>> {
    vec![Box::new(EnrolledSched::new())]
}
