//! Fixture: wall-clock time sources in library code are flagged
//! (expected findings: lines 4, 8 and 12; the doc prose, the
//! `instantaneous` identifier, and the `#[cfg(test)]` use must not fire).
use std::time::Instant;

/// Doc prose naming SystemTime or Instant is not a finding.
pub fn wall_time<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    let elapsed = t0.elapsed().as_secs_f64();
    // A second time source on the same path:
    let _epoch = std::time::SystemTime::now();
    elapsed
}

pub fn instantaneous_rate() -> u64 {
    // `Instantiate` / `instantaneous` are different words.
    let instantaneous = 7;
    instantaneous
}

#[cfg(test)]
mod tests {
    #[test]
    fn timing_in_tests_is_fine() {
        let _ = std::time::Instant::now();
    }
}
