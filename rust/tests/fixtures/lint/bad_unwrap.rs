//! Fixture: unwrap/expect/panic! outside test regions are flagged
//! (expected findings: lines 5, 9 and 13; the unwrap inside the
//! `#[cfg(test)]` module must NOT be flagged).
pub fn first(v: &[u32]) -> u32 {
    *v.first().unwrap()
}

pub fn must(v: Option<u32>) -> u32 {
    v.expect("present")
}

pub fn boom() {
    panic!("no");
}

#[cfg(test)]
mod tests {
    #[test]
    fn in_tests_unwrap_is_fine() {
        assert_eq!(super::must(Some(1)), 1);
        assert_eq!(Some(2).unwrap(), 2);
    }
}
