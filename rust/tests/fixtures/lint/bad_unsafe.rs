//! Fixture: `unsafe` without an adjacent `// SAFETY:` comment
//! (expected finding: line 4).
pub fn read_first(p: *const u32) -> u32 {
    unsafe { *p }
}
