//! Fixture: `HashMap` in a scheduler-scoped path must be flagged
//! (expected findings: lines 3 and 6 when lexed as `src/sched/...`).
use std::collections::HashMap;

pub fn count(xs: &[u32]) -> usize {
    let mut m: HashMap<u32, u32> = HashMap::new();
    for &x in xs {
        *m.entry(x).or_default() += 1;
    }
    m.len()
}
