//! Degenerate equivalence: `SpaceTimeScheduler::spatial_only()` (the
//! `spacetime` algo with temporal sharing disabled) must be
//! indistinguishable from `ElasticPartitioning::gpulet_int()` — same
//! verdict, same `Schedule`, and byte-identical harness JSON on the
//! Fig-12/13-style searches. The combined mode is pinned elsewhere as a
//! strict acceptance superset; this file pins the *floor* of that claim:
//! with the temporal axis off, nothing changes at all.

use gpulets::experiments::common::{
    eval_workloads, max_achievable_detail, max_schedulable, paper_ctx, scaled, violation_rate_of,
};
use gpulets::sched::{ElasticPartitioning, SchedCtx, Scheduler, SpaceTimeScheduler};
use gpulets::util::json::{obj, Json};
use gpulets::workload::enumerate_all_scenarios;

/// The Fig-12/13 numbers for one workload, rendered exactly the way the
/// experiment harnesses render them, so string equality is byte
/// equality of the emitted JSON.
fn harness_row(
    ctx: &SchedCtx,
    scheduler: &dyn Scheduler,
    name: &str,
    base: &[f64; 5],
) -> Json {
    let k = max_schedulable(ctx, scheduler, base);
    let viol = if k > 0.0 {
        let schedule = scheduler
            .schedule(ctx, &scaled(base, k))
            .expect("max_schedulable scale must be schedulable");
        violation_rate_of(ctx, &schedule, &scaled(base, k), 4.0, 131)
    } else {
        0.0
    };
    let a = max_achievable_detail(ctx, scheduler, base, 0.1, 4.0);
    obj(vec![
        ("workload", Json::Str(name.into())),
        ("max_schedulable_scale", Json::Num(k)),
        ("violation_rate_at_max", Json::Num(viol)),
        ("achieved_scale", Json::Num(a.scale)),
        ("achieved_rps", Json::Num(a.total_rps)),
        (
            "achieved_violation_rate",
            a.violation_rate.map_or(Json::Null, Json::Num),
        ),
    ])
}

#[test]
fn spatial_only_matches_elastic_verdicts_and_schedules() {
    let spatial = SpaceTimeScheduler::spatial_only();
    let elastic = ElasticPartitioning::gpulet_int();
    let scenarios = enumerate_all_scenarios();
    for interference_aware in [false, true] {
        let ctx = paper_ctx(interference_aware);
        for sc in scenarios.iter().step_by(11) {
            match (spatial.schedule(&ctx, &sc.rates), elastic.schedule(&ctx, &sc.rates)) {
                (Ok(a), Ok(b)) => assert_eq!(
                    a, b,
                    "{}: spatial-only diverged from elastic (intf {interference_aware})",
                    sc.name
                ),
                (Err(a), Err(b)) => assert_eq!(
                    a.to_string(),
                    b.to_string(),
                    "{}: rejection reasons diverged (intf {interference_aware})",
                    sc.name
                ),
                (a, b) => panic!(
                    "{}: verdicts diverged (intf {interference_aware}): \
                     spatial {:?} vs elastic {:?}",
                    sc.name,
                    a.map(|s| s.lets.len()),
                    b.map(|s| s.lets.len())
                ),
            }
        }
    }
}

#[test]
fn spatial_only_fig12_fig13_json_is_byte_identical_to_elastic() {
    // Two evaluation workloads through the full Fig-12 (max achievable
    // under a violation budget) and Fig-13 (max schedulable + measured
    // violation rate) searches. Both searches end in simulations, so
    // equality here means equality of every verdict along the doubling/
    // bisection bracket, of the emitted schedule at each probed scale,
    // and of the simulated outcome — i.e. true degeneracy, not just a
    // matching headline number.
    let ctx = paper_ctx(true);
    let spatial = SpaceTimeScheduler::spatial_only();
    let elastic = ElasticPartitioning::gpulet_int();
    let picks: Vec<(String, [f64; 5])> = eval_workloads()
        .into_iter()
        .filter(|(name, _)| name == "equal" || name == "long-only")
        .collect();
    assert_eq!(picks.len(), 2, "expected the equal + long-only workloads");
    let rows = |s: &dyn Scheduler| -> String {
        let rows: Vec<Json> = picks
            .iter()
            .map(|(name, base)| harness_row(&ctx, s, name, base))
            .collect();
        Json::Arr(rows).to_string()
    };
    assert_eq!(rows(&spatial), rows(&elastic));
}
