//! The `game` multi-model application (Fig 10) served end to end on the
//! simulated 4-GPU cluster: six parallel LeNet digit recognitions plus
//! one ResNet-50 image recognition per game frame.
//!
//! Shows the full pipeline: app -> induced model rates -> Elastic
//! Partitioning schedule -> discrete-event serving -> app-level
//! latency accounting (max over the parallel branches).
//!
//!     cargo run --release --example game_pipeline [app_fps]

use gpulets::apps::App;
use gpulets::coordinator::simserver::{simulate, SimConfig};
use gpulets::experiments::common::paper_ctx;
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{ElasticPartitioning, Scheduler};
use gpulets::workload::generate_arrivals;

fn main() -> gpulets::Result<()> {
    let fps: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120.0);
    let app = App::game();
    println!("== {} app at {fps} req/s ==", app.name);
    println!(
        "{} model invocations per request; app SLO {} ms",
        app.invocations_per_request(),
        app.slo_ms
    );

    let rates = app.induced_rates(fps);
    let ctx = paper_ctx(true);
    let scheduler = ElasticPartitioning::gpulet_int();
    let schedule = scheduler.schedule(&ctx, &rates)?;
    println!(
        "\nschedule: {} gpu-lets, {}% of cluster allocated",
        schedule.lets.len(),
        schedule.total_allocated_pct()
    );
    for lp in &schedule.lets {
        let asg: Vec<String> = lp
            .assignments
            .iter()
            .map(|a| format!("{}@b{} {:.0}r/s", a.model.abbrev(), a.batch, a.rate))
            .collect();
        println!("  gpu{} {:>3}%: {}", lp.spec.gpu, lp.spec.size_pct, asg.join(" + "));
    }

    let duration_s = 20.0;
    let pairs: Vec<(ModelId, f64)> = ModelId::ALL
        .iter()
        .map(|&m| (m, rates[m.index()]))
        .filter(|&(_, r)| r > 0.0)
        .collect();
    let arrivals = generate_arrivals(&pairs, duration_s, 33)?;
    let lm = LatencyModel::new();
    let report = simulate(
        &lm,
        &GroundTruth::default(),
        &schedule,
        &arrivals,
        duration_s,
        &SimConfig::default(),
    );
    println!("\ncomponent-level metrics:\n{}", report.table());

    // App-level latency estimate: the game frame completes when its
    // slowest branch does (critical path over p99 component latencies).
    let app_p99 = app.critical_path_ms(|m| {
        report.model(m).map_or(0.0, |mm| mm.p99_ms())
    });
    println!("app critical-path p99: {app_p99:.1} ms (SLO {} ms)", app.slo_ms);
    println!(
        "overall component SLO violations: {:.2}%",
        report.overall_violation_rate() * 100.0
    );
    Ok(())
}
