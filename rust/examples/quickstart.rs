//! Quickstart: the END-TO-END validation run (real clock, all layers).
//!
//! Loads the AOT artifacts produced by `make artifacts` (L1 Pallas
//! kernels inside L2 JAX models, lowered to HLO text), compiles them on
//! the PJRT CPU client, and serves a Poisson multi-model request mix
//! through the L3 duty-cycle batcher — reporting per-model latency,
//! SLO compliance, throughput, and PJRT busy time.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Python is not involved: only `artifacts/*.hlo.txt` + this binary.

use gpulets::coordinator::server::RealServer;
use gpulets::models::ModelId;
use gpulets::runtime::{Engine, ModelRegistry};
use gpulets::workload::generate_arrivals;

fn main() -> gpulets::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("== gpu-lets quickstart (real PJRT serving) ==");

    let engine = Engine::cpu()?;
    println!(
        "PJRT platform: {} ({} device(s))",
        engine.platform(),
        engine.device_count()
    );
    let registry = ModelRegistry::load(&engine, &artifacts)?;
    println!(
        "compiled {} (model, batch) executables from {}/",
        registry.len(),
        artifacts
    );

    // A small mixed workload at CPU-scale rates (the simulated-GPU
    // experiments use paper-scale rates; here the CPU PJRT client is
    // the actual executor — interpret-mode Pallas kernels run ~1000x
    // slower than the 2080 Ti the SLOs were written for).
    let rates = [
        (ModelId::Lenet, 16.0),
        (ModelId::Googlenet, 3.0),
        (ModelId::Resnet, 2.0),
        (ModelId::SsdMobilenet, 2.0),
        (ModelId::Vgg, 2.0),
    ];
    let duration_s = 4.0;
    let arrivals = generate_arrivals(&rates, duration_s, 7)?;
    println!(
        "\nserving {} requests over {duration_s} s (trace replay)...",
        arrivals.len()
    );

    let mut server = RealServer::new(&registry);
    // CPU-profiled batch choices (interpret-mode batch cost is
    // superlinear, so big models serve small batches here).
    server.batch = [
        (ModelId::Lenet, 8u32),
        (ModelId::Googlenet, 2),
        (ModelId::Resnet, 1),
        (ModelId::SsdMobilenet, 2),
        (ModelId::Vgg, 1),
    ]
    .into_iter()
    .collect();
    println!("(CPU substrate: SLOs scaled by {}x — see DESIGN.md §3)", server.slo_scale);
    let outcome = server.serve(&arrivals, duration_s)?;

    println!("\n{}", outcome.report.table());
    println!(
        "throughput: {:.0} req/s   goodput: {:.0} req/s",
        outcome.report.throughput_rps(),
        outcome.report.goodput_rps()
    );
    println!(
        "PJRT busy: {:.2} s across {} batches",
        outcome.exec_wall_s,
        outcome.batches.values().sum::<u64>()
    );
    println!("\nquickstart OK — all three layers composed.");
    Ok(())
}
