//! Rate-fluctuation adaptation demo (the Fig 14 experiment, compact):
//! the adaptive server re-schedules every 20 s while two load waves
//! sweep through, growing and shrinking gpu-let allocations.
//!
//!     cargo run --release --example fluctuating_load [duration_s]

use gpulets::coordinator::AdaptiveServer;
use gpulets::experiments::common::paper_ctx;
use gpulets::models::ModelId;
use gpulets::sched::ElasticPartitioning;
use gpulets::workload::FluctuationTrace;

fn main() {
    let duration_s: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(600.0);
    let ctx = paper_ctx(false);
    let scheduler = ElasticPartitioning::gpulet();
    let server = AdaptiveServer::new(&ctx, &scheduler);
    let trace = FluctuationTrace::default();

    println!("== adaptive serving over a fluctuating trace ({duration_s} s) ==");
    println!("t(s)  total-req/s  alloc%  viol%  reorg");
    let outcome = server
        .run_trace(&trace, duration_s, 2024)
        .expect("trace rates are finite");
    let stats = &outcome.windows;
    for w in stats {
        let total: f64 = w.throughput.iter().sum();
        let bar_len = (w.allocated_pct / 10) as usize;
        println!(
            "{:>4.0} {:>12.0} {:>7} {:>6.2} {:>6} {}",
            w.t_start_s,
            total,
            w.allocated_pct,
            w.violation_rate * 100.0,
            if w.reorganized { "*" } else { "" },
            "#".repeat(bar_len),
        );
    }

    let offered: u64 = outcome.offered.iter().sum();
    println!(
        "\noverall violation share: {:.2}% of {offered} requests (paper Fig 14: 0.14%)",
        100.0 * outcome.overall_violation_share()
    );
    let peak = stats.iter().map(|w| w.allocated_pct).max().unwrap_or(0);
    let trough = stats.iter().map(|w| w.allocated_pct).min().unwrap_or(0);
    println!("allocation range: {trough}%..{peak}% of the 400% cluster");
    let _ = ModelId::ALL; // (doc hint: per-model series available in WindowStats)
}
