//! The `traffic` surveillance application (Fig 11) on the simulated
//! cluster: SSD-MobileNet object detection feeding GoogLeNet and
//! VGG-16 recognizers (two stages), per camera frame.
//!
//! Compares the four schedulers on the same offered load, then runs
//! the chosen schedule through the simulator.
//!
//!     cargo run --release --example traffic_pipeline [camera_fps]

use gpulets::apps::App;
use gpulets::coordinator::simserver::{simulate, SimConfig};
use gpulets::experiments::common::paper_ctx;
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{
    ElasticPartitioning, GuidedSelfTuning, Scheduler, SquishyBinPacking,
};
use gpulets::workload::generate_arrivals;

fn main() -> gpulets::Result<()> {
    let fps: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150.0);
    let app = App::traffic();
    println!("== {} app at {fps} frames/s ==", app.name);
    let rates = app.induced_rates(fps);

    // Which schedulers even accept this load?
    let ctx = paper_ctx(false);
    let ctx_int = paper_ctx(true);
    let sbp = SquishyBinPacking::baseline();
    let st = GuidedSelfTuning;
    let gp = ElasticPartitioning::gpulet();
    let gi = ElasticPartitioning::gpulet_int();
    println!("\nscheduler admission at this rate:");
    for (name, ok) in [
        ("sbp", sbp.schedule(&ctx, &rates).is_ok()),
        ("selftune", st.schedule(&ctx, &rates).is_ok()),
        ("gpulet", gp.schedule(&ctx, &rates).is_ok()),
        ("gpulet+int", gi.schedule(&ctx_int, &rates).is_ok()),
    ] {
        println!("  {name:<11} {}", if ok { "Schedulable" } else { "NOT schedulable" });
    }

    let schedule = gi.schedule(&ctx_int, &rates)?;
    println!(
        "\ngpulet+int schedule ({}% allocated):",
        schedule.total_allocated_pct()
    );
    for lp in &schedule.lets {
        let asg: Vec<String> = lp
            .assignments
            .iter()
            .map(|a| format!("{}@b{} {:.0}r/s", a.model.abbrev(), a.batch, a.rate))
            .collect();
        println!("  gpu{} {:>3}%: {}", lp.spec.gpu, lp.spec.size_pct, asg.join(" + "));
    }

    let duration_s = 20.0;
    let pairs: Vec<(ModelId, f64)> = ModelId::ALL
        .iter()
        .map(|&m| (m, rates[m.index()]))
        .filter(|&(_, r)| r > 0.0)
        .collect();
    let arrivals = generate_arrivals(&pairs, duration_s, 44)?;
    let report = simulate(
        &LatencyModel::new(),
        &GroundTruth::default(),
        &schedule,
        &arrivals,
        duration_s,
        &SimConfig::default(),
    );
    println!("\n{}", report.table());

    // Two-stage app latency: SSD p99, then max(GoogLeNet, VGG) p99.
    let p99 = |m: ModelId| report.model(m).map_or(0.0, |mm| mm.p99_ms());
    let app_p99 = p99(ModelId::SsdMobilenet)
        + p99(ModelId::Googlenet).max(p99(ModelId::Vgg));
    println!("app two-stage p99: {app_p99:.1} ms (SLO {} ms)", app.slo_ms);
    Ok(())
}
