//! Schedulability sweep: all four practical schedulers + the ideal
//! exhaustive search over the paper's 1,023-scenario population
//! (Fig 4 + Fig 15 in one table).
//!
//!     cargo run --release --example schedulability_sweep

use std::time::Instant;

use gpulets::experiments::common::paper_ctx;
use gpulets::sched::{
    ElasticPartitioning, GuidedSelfTuning, IdealScheduler, Scheduler,
    SquishyBinPacking,
};
use gpulets::workload::enumerate_all_scenarios;

fn main() {
    let ctx = paper_ctx(false);
    let ctx_int = paper_ctx(true);
    let scenarios = enumerate_all_scenarios();
    println!(
        "== schedulability over {} scenarios (4 GPUs, rates 0/200/400/600) ==",
        scenarios.len()
    );

    // `+ '_`: the boxed closures borrow the contexts above.
    let runs: Vec<(&str, Box<dyn Fn(&[f64; 5]) -> bool + '_>)> = vec![
        ("sbp", {
            let s = SquishyBinPacking::baseline();
            let c = &ctx;
            Box::new(move |r| s.schedule(c, r).is_ok())
        }),
        ("sbp+50:50", {
            let s = SquishyBinPacking::with_even_partitioning();
            let c = &ctx;
            Box::new(move |r| s.schedule(c, r).is_ok())
        }),
        ("selftune", {
            let s = GuidedSelfTuning;
            let c = &ctx;
            Box::new(move |r| s.schedule(c, r).is_ok())
        }),
        ("gpulet", {
            let s = ElasticPartitioning::gpulet();
            let c = &ctx;
            Box::new(move |r| s.schedule(c, r).is_ok())
        }),
        ("gpulet+int", {
            let s = ElasticPartitioning::gpulet_int();
            let c = &ctx_int;
            Box::new(move |r| s.schedule(c, r).is_ok())
        }),
        ("ideal", {
            let s = IdealScheduler;
            let c = &ctx;
            Box::new(move |r| s.schedule(c, r).is_ok())
        }),
    ];

    println!("{:<12} {:>11} {:>9}", "scheduler", "schedulable", "time");
    for (name, ok) in &runs {
        let t0 = Instant::now();
        let n = scenarios.iter().filter(|sc| ok(&sc.rates)).count();
        println!(
            "{:<12} {:>6} /1023 {:>8.2?}",
            name,
            n,
            t0.elapsed()
        );
    }
}
