"""L1 fused elementwise + pooling kernels vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import (
    add_act,
    avgpool2d,
    bias_act,
    global_avgpool,
    maxpool2d,
)
from compile.kernels import ref

RNG = np.random.default_rng(13)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape), np.float32)


@pytest.mark.parametrize("act", ["relu", "none"])
@pytest.mark.parametrize("shape", [(4, 8), (2, 5, 5, 3), (1, 10)])
def test_bias_act(shape, act):
    x = _rand(shape)
    b = _rand((shape[-1],))
    np.testing.assert_allclose(
        bias_act(x, b, act=act), ref.bias_act(x, b, act=act), rtol=1e-5, atol=1e-6
    )


def test_bias_act_relu_clamps():
    x = jnp.asarray([[-5.0, 5.0]], jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(bias_act(x, b)), [[0.0, 5.0]])


def test_bias_act_rejects_bad_bias():
    with pytest.raises(ValueError):
        bias_act(_rand((2, 3)), _rand((4,)))
    with pytest.raises(ValueError):
        bias_act(_rand((2, 3)), _rand((3, 1)))
    with pytest.raises(ValueError):
        bias_act(_rand((2, 3)), _rand((3,)), act="gelu")


@pytest.mark.parametrize("act", ["relu", "none"])
def test_add_act(act):
    x, y = _rand((2, 4, 4, 3)), _rand((2, 4, 4, 3))
    np.testing.assert_allclose(
        add_act(x, y, act=act), ref.add_act(x, y, act=act), rtol=1e-5, atol=1e-6
    )


def test_add_act_rejects_mismatch():
    with pytest.raises(ValueError):
        add_act(_rand((2, 3)), _rand((3, 2)))


@pytest.mark.parametrize("k", [2, 3])
@pytest.mark.parametrize("shape", [(1, 8, 8, 2), (2, 9, 7, 3), (1, 6, 6, 1)])
def test_maxpool(shape, k):
    x = _rand(shape)
    got, want = maxpool2d(x, k=k), ref.maxpool2d(x, k=k)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("k", [2, 3])
def test_avgpool(k):
    x = _rand((2, 12, 12, 4))
    np.testing.assert_allclose(
        avgpool2d(x, k=k), ref.avgpool2d(x, k=k), rtol=1e-5, atol=1e-6
    )


def test_global_avgpool():
    x = _rand((3, 7, 5, 6))
    got = global_avgpool(x)
    assert got.shape == (3, 6)
    np.testing.assert_allclose(got, ref.global_avgpool(x), rtol=1e-5, atol=1e-6)


def test_maxpool_constant_regions():
    x = jnp.full((1, 4, 4, 1), 3.5, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(maxpool2d(x, k=2)), np.full((1, 2, 2, 1), 3.5)
    )
