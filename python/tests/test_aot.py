"""AOT pipeline checks: HLO text validity, manifest integrity, determinism."""

import json
import os

import pytest

from compile.aot import artifact_name, emit, lower_model
from compile.model import CATALOG


def test_lower_model_produces_hlo_text():
    text, in_shape, out_shape = lower_model("lenet", 2)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert in_shape == (2, 28, 28, 1)
    assert out_shape == (2, 10)


def test_hlo_text_has_no_custom_calls():
    """interpret=True Pallas must lower to plain HLO ops the CPU PJRT
    client can execute — a Mosaic custom-call would break the Rust side."""
    for name in ("lenet", "ssd_mobilenet"):
        text, _, _ = lower_model(name, 1)
        assert "custom-call" not in text, f"{name} lowered with a custom-call"


def test_hlo_text_has_no_elided_constants():
    """Weights must be printed in full: `constant({...})` elision parses
    as zeros in the Rust HLO-text loader (regression guard)."""
    text, _, _ = lower_model("lenet", 1)
    assert "constant({...})" not in text
    # The fc1 weight (784x120) must appear with real digits.
    assert "f32[784,120]" in text


def test_emit_writes_golden_vectors(tmp_path):
    manifest = emit(str(tmp_path), models=["lenet"], batches=(1,), verbose=False)
    golden = manifest["models"]["lenet"]["golden"]
    assert golden["batch"] == 1
    assert len(golden["output"]) == 10
    assert any(abs(v) > 1e-6 for v in golden["output"])


def test_lower_model_deterministic():
    a, _, _ = lower_model("lenet", 1)
    b, _, _ = lower_model("lenet", 1)
    assert a == b


def test_emit_manifest(tmp_path):
    outdir = str(tmp_path)
    manifest = emit(outdir, models=["lenet"], batches=(1, 2), verbose=False)
    assert manifest["batch_sizes"] == [1, 2]
    entry = manifest["models"]["lenet"]
    assert entry["slo_ms"] == CATALOG["lenet"].slo_ms
    for b in (1, 2):
        art = entry["artifacts"][str(b)]
        path = os.path.join(outdir, art["file"])
        assert os.path.exists(path)
        with open(path) as f:
            assert "HloModule" in f.read(200)
        assert art["input_shape"][0] == b
    # manifest.json round-trips
    with open(os.path.join(outdir, "manifest.json")) as f:
        disk = json.load(f)
    assert disk == manifest


def test_artifact_name_format():
    assert artifact_name("vgg", 32) == "vgg_b32.hlo.txt"


@pytest.mark.slow
def test_repo_artifacts_if_present():
    """If `make artifacts` already ran, validate the real manifest."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art_dir, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts/ not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert set(manifest["models"]) == set(CATALOG)
    for name, entry in manifest["models"].items():
        for b, art in entry["artifacts"].items():
            assert os.path.exists(os.path.join(art_dir, art["file"])), (
                f"missing artifact {art['file']}"
            )
