"""Hypothesis sweeps over L1 kernel shapes/dtypes vs the ref oracle.

Property-based coverage required by the build brief: random shapes,
random block configs, random strides — every draw must match ref.py.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    add_act,
    bias_act,
    conv2d,
    depthwise_conv2d,
    matmul,
    maxpool2d,
)
from compile.kernels import ref

_SETTINGS = dict(max_examples=25, deadline=None)


def _arr(rng_seed, shape, dtype):
    rng = np.random.default_rng(rng_seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


@settings(**_SETTINGS)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    bm=st.sampled_from([8, 16, 32, 64]),
    bn=st.sampled_from([8, 16, 32, 64]),
    bk=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_matmul_any_shape_any_blocks(m, k, n, bm, bn, bk, seed):
    x = _arr(seed, (m, k), np.float32)
    w = _arr(seed + 1, (k, n), np.float32)
    got = matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(got, ref.matmul(x, w), rtol=1e-3, atol=1e-3)


@settings(**_SETTINGS)
@given(
    dtype=st.sampled_from([np.float32, jnp.bfloat16]),
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**16),
)
def test_matmul_dtypes(dtype, m, k, n, seed):
    x = _arr(seed, (m, k), np.float32).astype(dtype)
    w = _arr(seed + 1, (k, n), np.float32).astype(dtype)
    out = matmul(x, w)
    assert out.dtype == jnp.float32
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(out, ref.matmul(x, w), rtol=tol, atol=tol)


@settings(**_SETTINGS)
@given(
    n=st.integers(1, 3),
    h=st.integers(3, 14),
    w=st.integers(3, 14),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_random(n, h, w, cin, cout, k, stride, padding, seed):
    if padding == "VALID" and (h < k or w < k):
        return
    x = _arr(seed, (n, h, w, cin), np.float32)
    wgt = _arr(seed + 1, (k, k, cin, cout), np.float32)
    got = conv2d(x, wgt, stride=stride, padding=padding)
    want = ref.conv2d(x, wgt, stride=stride, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(**_SETTINGS)
@given(
    n=st.integers(1, 2),
    h=st.integers(3, 12),
    w=st.integers(3, 12),
    c=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_depthwise_random(n, h, w, c, stride, seed):
    x = _arr(seed, (n, h, w, c), np.float32)
    wgt = _arr(seed + 1, (3, 3, c), np.float32)
    got = depthwise_conv2d(x, wgt, stride=stride)
    want = ref.depthwise_conv2d(x, wgt, stride=stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(**_SETTINGS)
@given(
    rank=st.sampled_from([2, 4]),
    d=st.integers(1, 16),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**16),
)
def test_elementwise_random(rank, d, act, seed):
    shape = (2, d) if rank == 2 else (2, 3, 3, d)
    x = _arr(seed, shape, np.float32)
    y = _arr(seed + 1, shape, np.float32)
    b = _arr(seed + 2, (d,), np.float32)
    np.testing.assert_allclose(
        bias_act(x, b, act=act), ref.bias_act(x, b, act=act), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        add_act(x, y, act=act), ref.add_act(x, y, act=act), rtol=1e-5, atol=1e-5
    )


@settings(**_SETTINGS)
@given(
    h=st.integers(2, 16),
    w=st.integers(2, 16),
    c=st.integers(1, 4),
    k=st.sampled_from([2, 3]),
    seed=st.integers(0, 2**16),
)
def test_maxpool_random(h, w, c, k, seed):
    if h < k or w < k:
        return
    x = _arr(seed, (1, h, w, c), np.float32)
    got, want = maxpool2d(x, k=k), ref.maxpool2d(x, k=k)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-6)
