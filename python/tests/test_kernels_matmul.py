"""L1 matmul kernel vs pure-jnp oracle — the core correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import matmul
from compile.kernels import ref
from compile.kernels.matmul import mxu_utilization_estimate, vmem_footprint_bytes

RNG = np.random.default_rng(7)


def _rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (1, 784, 120),  # LeNet fc1 at batch 1
        (8, 64, 64),
        (17, 33, 9),  # deliberately non-multiple of any block
        (64, 64, 64),  # exactly one block
        (65, 64, 64),  # one row over a block boundary
        (128, 256, 96),
        (200, 150, 75),
    ],
)
def test_matmul_matches_ref(m, k, n):
    x, w = _rand((m, k)), _rand((k, n))
    np.testing.assert_allclose(
        matmul(x, w), ref.matmul(x, w), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("bm,bn,bk", [(16, 16, 16), (32, 64, 16), (64, 64, 64)])
def test_matmul_block_shapes_equivalent(bm, bn, bk):
    x, w = _rand((50, 70)), _rand((70, 30))
    got = matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(got, ref.matmul(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_bf16_inputs_accumulate_f32():
    x = _rand((32, 32)).astype(jnp.bfloat16)
    w = _rand((32, 32)).astype(jnp.bfloat16)
    out = matmul(x, w)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(
        out, ref.matmul(x, w), rtol=2e-2, atol=2e-2
    )


def test_matmul_zero_blocks_do_not_pollute():
    # Padding regions must contribute exactly zero.
    x = jnp.ones((3, 5), jnp.float32)
    w = jnp.ones((5, 2), jnp.float32)
    np.testing.assert_array_equal(np.asarray(matmul(x, w)), np.full((3, 2), 5.0))


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul(jnp.ones((2, 3)), jnp.ones((4, 2)))
    with pytest.raises(ValueError):
        matmul(jnp.ones((2, 3, 4)), jnp.ones((4, 2)))


def test_vmem_footprint_under_budget():
    # Default blocks must fit comfortably in a 16 MiB VMEM budget.
    assert vmem_footprint_bytes() < 16 * 1024 * 1024 // 4


def test_mxu_utilization_estimate_bounds():
    assert mxu_utilization_estimate(64, 64, 64) == pytest.approx(1.0)
    frac = mxu_utilization_estimate(65, 64, 64)
    assert 0.0 < frac < 1.0
    # Exact: 65*64*64 useful over 128*64*64 padded
    assert frac == pytest.approx(65 / 128)
