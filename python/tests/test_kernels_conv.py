"""L1 conv kernels (im2col+matmul, depthwise) vs lax oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import conv2d, depthwise_conv2d
from compile.kernels import ref

RNG = np.random.default_rng(11)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape), np.float32)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize(
    "n,h,w,cin,cout,k",
    [
        (1, 8, 8, 1, 4, 3),
        (2, 10, 10, 3, 5, 3),
        (1, 28, 28, 1, 6, 5),  # LeNet c1
        (3, 7, 9, 2, 3, 3),  # non-square spatial
        (1, 5, 5, 4, 4, 1),  # 1x1 projection
    ],
)
def test_conv2d_matches_ref(n, h, w, cin, cout, k, stride, padding):
    x = _rand((n, h, w, cin))
    wgt = _rand((k, k, cin, cout))
    got = conv2d(x, wgt, stride=stride, padding=padding)
    want = ref.conv2d(x, wgt, stride=stride, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_explicit_padding():
    x = _rand((1, 6, 6, 2))
    wgt = _rand((3, 3, 2, 3))
    pad = ((2, 0), (0, 2))
    np.testing.assert_allclose(
        conv2d(x, wgt, padding=pad),
        ref.conv2d(x, wgt, padding=pad),
        rtol=1e-4,
        atol=1e-4,
    )


def test_conv2d_rejects_channel_mismatch():
    with pytest.raises(ValueError):
        conv2d(_rand((1, 4, 4, 3)), _rand((3, 3, 2, 4)))


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize(
    "n,h,w,c,k",
    [(1, 8, 8, 3, 3), (2, 10, 10, 4, 3), (1, 9, 7, 2, 5)],
)
def test_depthwise_matches_ref(n, h, w, c, k, stride):
    x = _rand((n, h, w, c))
    wgt = _rand((k, k, c))
    got = depthwise_conv2d(x, wgt, stride=stride)
    want = ref.depthwise_conv2d(x, wgt, stride=stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_depthwise_rejects_channel_mismatch():
    with pytest.raises(ValueError):
        depthwise_conv2d(_rand((1, 4, 4, 3)), _rand((3, 3, 5)))


def test_conv2d_identity_kernel():
    # 1x1 identity conv must reproduce the input exactly.
    x = _rand((2, 6, 6, 3))
    eye = jnp.eye(3, dtype=jnp.float32).reshape(1, 1, 3, 3)
    np.testing.assert_allclose(conv2d(x, eye), x, rtol=1e-6, atol=1e-6)
