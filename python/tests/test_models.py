"""L2 model forward-pass checks: shapes, determinism, finiteness, batching."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import BATCH_SIZES, CATALOG, MODEL_NAMES, build_model


@pytest.mark.parametrize("name", MODEL_NAMES)
def test_model_shapes(name):
    fn, ex = build_model(name, 3)
    info = CATALOG[name]
    assert ex.shape == (3,) + tuple(info.input_shape)
    out = fn(ex)
    assert out.shape[0] == 3
    assert out.ndim == 2


@pytest.mark.parametrize("name", MODEL_NAMES)
def test_model_outputs_finite(name):
    fn, ex = build_model(name, 2)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(ex.shape), jnp.float32)
    out = np.asarray(fn(x))
    assert np.isfinite(out).all()
    # Seeded-init nets on random input must not be degenerate (all-zero).
    assert np.abs(out).max() > 0


@pytest.mark.parametrize("name", MODEL_NAMES)
def test_model_deterministic_params(name):
    """Two builds must produce identical outputs (reproducible artifacts)."""
    fn1, ex = build_model(name, 1)
    fn2, _ = build_model(name, 1)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(ex.shape), jnp.float32)
    np.testing.assert_array_equal(np.asarray(fn1(x)), np.asarray(fn2(x)))


@pytest.mark.parametrize("name", MODEL_NAMES)
def test_model_batch_consistency(name):
    """Row i of a batched forward equals the single-sample forward."""
    fn4, ex4 = build_model(name, 4)
    fn1, _ = build_model(name, 1)
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal(ex4.shape), jnp.float32)
    batched = np.asarray(fn4(x))
    for i in range(4):
        single = np.asarray(fn1(x[i : i + 1]))
        np.testing.assert_allclose(batched[i], single[0], rtol=1e-4, atol=1e-4)


def test_catalog_slos_match_paper_table4():
    assert CATALOG["lenet"].slo_ms == 5.0
    assert CATALOG["googlenet"].slo_ms == 44.0
    assert CATALOG["resnet"].slo_ms == 95.0
    assert CATALOG["ssd_mobilenet"].slo_ms == 136.0
    assert CATALOG["vgg"].slo_ms == 130.0


def test_batch_sizes_cover_paper_sweep():
    assert BATCH_SIZES == (1, 2, 4, 8, 16, 32)


def test_build_model_rejects_bad_args():
    with pytest.raises(KeyError):
        build_model("alexnet", 1)
    with pytest.raises(ValueError):
        build_model("lenet", 0)
