"""Conv2d Pallas kernels.

Regular convolution is lowered as im2col (cheap data movement expressed
with `lax.conv_general_dilated_patches`) followed by the tiled Pallas
matmul — the same decomposition cuDNN-style GPU serving stacks use, so
the hot FLOPs all flow through the L1 matmul kernel.

Depthwise convolution (MobileNet-style) has no matmul form with useful
arithmetic intensity; it gets its own fused multiply-reduce Pallas
kernel over extracted patches.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .matmul import matmul


def _same_pad(size, k, stride):
    """XLA-convention SAME padding for one spatial dim."""
    out = -(-size // stride)  # ceil
    total = max((out - 1) * stride + k - size, 0)
    return (total // 2, total - total // 2)


def _normalize_padding(padding, kh, kw, h, w, stride):
    if padding == "SAME":
        return (_same_pad(h, kh, stride), _same_pad(w, kw, stride))
    if padding == "VALID":
        return ((0, 0), (0, 0))
    return tuple(padding)


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d(x, w, *, stride: int = 1, padding="SAME"):
    """NHWC conv: x (N,H,W,Cin), w (kh,kw,Cin,Cout) -> (N,H',W',Cout).

    im2col + Pallas tiled matmul. f32 accumulate.
    """
    n, h, wdt, cin = x.shape
    kh, kw, wcin, cout = w.shape
    if wcin != cin:
        raise ValueError(f"channel mismatch: x has {cin}, w expects {wcin}")
    pad = _normalize_padding(padding, kh, kw, h, wdt, stride)

    # patches: (N, Cin*kh*kw, H', W') with feature dim ordered (cin, kh, kw).
    patches = lax.conv_general_dilated_patches(
        jnp.transpose(x, (0, 3, 1, 2)),  # NCHW
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=pad,
    )
    _, feat, ho, wo = patches.shape
    cols = jnp.transpose(patches, (0, 2, 3, 1)).reshape(n * ho * wo, feat)
    # Reorder w (kh,kw,cin,cout) -> (cin,kh,kw,cout) to match patch order.
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(feat, cout)
    out = matmul(cols, wmat)
    return out.reshape(n, ho, wo, cout)


def _dw_kernel(p_ref, w_ref, o_ref):
    """Fused multiply-reduce: o[n,s,c] = sum_t p[n,s,c,t] * w[c,t]."""
    o_ref[...] = jnp.sum(p_ref[...] * w_ref[...][None, None, :, :], axis=-1)


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def depthwise_conv2d(x, w, *, stride: int = 1, padding="SAME"):
    """NHWC depthwise conv: x (N,H,W,C), w (kh,kw,C) -> (N,H',W',C)."""
    n, h, wdt, c = x.shape
    kh, kw, wc = w.shape
    if wc != c:
        raise ValueError(f"channel mismatch: x has {c}, w expects {wc}")
    pad = _normalize_padding(padding, kh, kw, h, wdt, stride)

    patches = lax.conv_general_dilated_patches(
        jnp.transpose(x, (0, 3, 1, 2)),
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=pad,
    )  # (N, C*kh*kw, H', W'), feature ordered (c, kh, kw)
    _, feat, ho, wo = patches.shape
    taps = kh * kw
    p = jnp.transpose(patches, (0, 2, 3, 1)).reshape(n, ho * wo, c, taps)
    wmat = jnp.transpose(w, (2, 0, 1)).reshape(c, taps)

    out = pl.pallas_call(
        _dw_kernel,
        out_shape=jax.ShapeDtypeStruct((n, ho * wo, c), jnp.float32),
        interpret=True,
    )(p.astype(jnp.float32), wmat.astype(jnp.float32))
    return out.reshape(n, ho, wo, c)
