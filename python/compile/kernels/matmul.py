"""Tiled matmul Pallas kernel — the compute hot-spot of every served model.

TPU mapping of the paper's GPU insight (DESIGN.md §Hardware-Adaptation):
the grid tiles (M, N, K) into VMEM-resident blocks; each grid step feeds
one (block_m x block_k) @ (block_k x block_n) MXU matmul and accumulates
into the output block. BlockSpec expresses the HBM<->VMEM schedule the
paper expressed with threadblocks; the partition fraction of a gpu-let
corresponds to the share of parallel grid lanes available.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 64x64 f32 blocks keep the working set
# (bm*bk + bk*bn + bm*bn) * 4B = 48 KiB far under a ~16 MiB VMEM budget
# while remaining MXU-shaped (multiples of 8x128 lanes after padding).
DEFAULT_BLOCK_M = 64
DEFAULT_BLOCK_N = 64
DEFAULT_BLOCK_K = 64


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Grid step (i, j, k): o[i,j] += x[i,k] @ w[k,j], zero-init at k==0."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def effective_block(block: int, dim: int) -> int:
    """Block actually used for a dimension of size `dim`: clamped to the
    problem, rounded up to a multiple of 8 for MXU lane alignment."""
    r8 = -(-max(dim, 1) // 8) * 8
    return min(block, r8)


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k")
)
def matmul(
    x,
    w,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
):
    """`x @ w` for 2-D f32/bf16 operands via the tiled Pallas kernel.

    Inputs are zero-padded up to block multiples and the result is
    sliced back, so arbitrary (m, k) x (k, n) shapes are accepted.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    m, k = x.shape
    _, n = w.shape

    # Clamp blocks to the problem so tiny layers stay one tile, but
    # keep them multiples of 8 (MXU sublane alignment): a 25-wide
    # contraction gets a 32-wide block, not a ragged 25-wide one.
    bm = effective_block(block_m, m)
    bn = effective_block(block_n, n)
    bk = effective_block(block_k, k)

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    mp, kp = xp.shape
    _, np_ = wp.shape

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp.astype(jnp.float32), wp.astype(jnp.float32))
    return out[:m, :n]


def vmem_footprint_bytes(
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    dtype_bytes: int = 4,
) -> int:
    """Per-grid-step VMEM residency: one x, w and o block (double-buffered x2)."""
    single = (block_m * block_k + block_k * block_n + block_m * block_n) * dtype_bytes
    return 2 * single


def mxu_utilization_estimate(
    m: int,
    n: int,
    k: int,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> float:
    """Useful-FLOP fraction after padding to block multiples — the share of
    MXU issue slots doing real work (structure-level estimate; interpret
    mode gives no hardware counters)."""

    def _ceil(a, b):
        return -(-a // b) * b

    useful = 2.0 * m * n * k
    padded = 2.0 * _ceil(m, block_m) * _ceil(n, block_n) * _ceil(k, block_k)
    return useful / padded
