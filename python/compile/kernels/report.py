"""L1 structure/perf report: VMEM footprint + MXU-utilization estimates.

interpret=True gives CPU-numpy timings, which are NOT a TPU proxy
(DESIGN.md §9): the optimization target at L1 is *structure* — block
shapes that fit VMEM with double-buffering and keep the MXU issue slots
full. This report prints, per served model, the matmul-kernel tiles its
layers lower to and their footprint/utilization estimates.

Usage: cd python && python -m compile.kernels.report
"""

from ..model import CATALOG, build_model
from .matmul import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_M,
    DEFAULT_BLOCK_N,
    effective_block,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)

#: The matmul problems each model's conv/dense layers lower to via
#: im2col at batch 8 (rows = batch * out_h * out_w, k = cin*kh*kw).
MODEL_MATMULS = {
    "lenet": [
        (8 * 28 * 28, 25, 6),
        (8 * 14 * 14, 150, 16),
        (8, 784, 120),
        (8, 120, 84),
        (8, 84, 10),
    ],
    "googlenet": [
        (8 * 32 * 32, 27, 16),
        (8 * 16 * 16, 16, 8),
        (8 * 16 * 16, 72, 16),
        (8 * 16 * 16, 100, 8),
        (8 * 16 * 16, 40, 16),
        (8, 64, 10),
    ],
    "resnet": [
        (8 * 32 * 32, 27, 16),
        (8 * 32 * 32, 144, 16),
        (8 * 16 * 16, 144, 32),
        (8 * 16 * 16, 288, 32),
        (8 * 8 * 8, 288, 64),
        (8, 64, 10),
    ],
    "ssd_mobilenet": [
        (8 * 38 * 38, 27, 16),
        (8 * 38 * 38, 16, 24),
        (8 * 19 * 19, 24, 32),
        (8 * 19 * 19, 32, 48),
        (8 * 10 * 10, 48, 64),
        (8 * 10 * 10, 576, 24),
        (8 * 10 * 10, 576, 16),
    ],
    "vgg": [
        (8 * 32 * 32, 27, 24),
        (8 * 32 * 32, 216, 24),
        (8 * 16 * 16, 216, 48),
        (8 * 16 * 16, 432, 48),
        (8 * 8 * 8, 432, 96),
        (8 * 8 * 8, 864, 96),
        (8, 1536, 128),
        (8, 128, 64),
        (8, 64, 10),
    ],
}

VMEM_BUDGET = 16 * 1024 * 1024  # 16 MiB scratchpad


def report() -> str:
    lines = ["# L1 kernel structure report (batch 8, default tiles)"]
    lines.append(
        f"tiles: bm={DEFAULT_BLOCK_M} bn={DEFAULT_BLOCK_N} bk={DEFAULT_BLOCK_K}; "
        f"per-step VMEM (double-buffered): {vmem_footprint_bytes() / 1024:.0f} KiB "
        f"({vmem_footprint_bytes() / VMEM_BUDGET * 100:.1f}% of 16 MiB budget)"
    )
    for name, mms in MODEL_MATMULS.items():
        # Use the tiles the kernel actually picks (clamped + 8-aligned).
        utils = [
            mxu_utilization_estimate(
                m, n, k,
                block_m=effective_block(DEFAULT_BLOCK_M, m),
                block_n=effective_block(DEFAULT_BLOCK_N, n),
                block_k=effective_block(DEFAULT_BLOCK_K, k),
            )
            for (m, k, n) in mms
        ]
        worst = min(utils)
        mean = sum(utils) / len(utils)
        lines.append(
            f"{name:<15} {len(mms)} matmuls  MXU-util mean {mean:.2f}  worst {worst:.2f}"
        )
    lines.append(
        "target: mean >= 0.5 of roofline issue slots (DESIGN.md §9)"
    )
    return "\n".join(lines)


def main() -> None:
    print(report())
    # Sanity: the catalog models actually build (keeps this report honest).
    for name in CATALOG:
        build_model(name, 1)


if __name__ == "__main__":
    main()
