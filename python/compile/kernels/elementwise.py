"""Fused elementwise Pallas kernels: bias+activation and residual add.

Fusing bias/activation into one kernel invocation keeps the activation
tensor resident in VMEM for a single HBM round-trip — the TPU analogue
of the epilogue fusion GPU serving stacks do in their conv kernels.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTS = ("relu", "none")


def _bias_act_kernel(act):
    def kernel(x_ref, b_ref, o_ref):
        y = x_ref[...] + b_ref[...]
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y

    return kernel


@functools.partial(jax.jit, static_argnames=("act",))
def bias_act(x, b, *, act: str = "relu"):
    """x + b (broadcast over trailing dim) then activation, fused."""
    if act not in _ACTS:
        raise ValueError(f"unknown act {act!r}; expected one of {_ACTS}")
    if b.ndim != 1 or x.shape[-1] != b.shape[0]:
        raise ValueError(f"bias shape {b.shape} incompatible with x {x.shape}")
    bb = jnp.broadcast_to(b, x.shape)
    return pl.pallas_call(
        _bias_act_kernel(act),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), bb.astype(jnp.float32))


def _add_act_kernel(act):
    def kernel(x_ref, y_ref, o_ref):
        z = x_ref[...] + y_ref[...]
        if act == "relu":
            z = jnp.maximum(z, 0.0)
        o_ref[...] = z

    return kernel


@functools.partial(jax.jit, static_argnames=("act",))
def add_act(x, y, *, act: str = "relu"):
    """Residual add then activation, fused (ResNet skip connections)."""
    if act not in _ACTS:
        raise ValueError(f"unknown act {act!r}; expected one of {_ACTS}")
    if x.shape != y.shape:
        raise ValueError(f"shape mismatch {x.shape} vs {y.shape}")
    return pl.pallas_call(
        _add_act_kernel(act),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
