"""Pooling Pallas kernels (NHWC)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref):
    # x block: (N, H', k, W', k, C) — reduce the two window axes.
    o_ref[...] = jnp.max(x_ref[...], axis=(2, 4))


def _avgpool_kernel(x_ref, o_ref):
    o_ref[...] = jnp.mean(x_ref[...], axis=(2, 4))


def _pool(x, k, kernel):
    n, h, w, c = x.shape
    if h % k or w % k:
        # Edge-crop like PyTorch's floor-mode pooling.
        x = x[:, : h - h % k, : w - w % k, :]
        n, h, w, c = x.shape
    xr = x.reshape(n, h // k, k, w // k, k, c)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, h // k, w // k, c), jnp.float32),
        interpret=True,
    )(xr.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("k",))
def maxpool2d(x, *, k: int = 2):
    """k x k max pool, stride k."""
    return _pool(x, k, _maxpool_kernel)


@functools.partial(jax.jit, static_argnames=("k",))
def avgpool2d(x, *, k: int = 2):
    """k x k average pool, stride k."""
    return _pool(x, k, _avgpool_kernel)


def _gap_kernel(x_ref, o_ref):
    o_ref[...] = jnp.mean(x_ref[...], axis=(1, 2))


@jax.jit
def global_avgpool(x):
    """(N,H,W,C) -> (N,C) global average pool."""
    n, h, w, c = x.shape
    return pl.pallas_call(
        _gap_kernel,
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
