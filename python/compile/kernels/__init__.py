"""Layer-1 Pallas kernels (interpret=True) used by the L2 models.

Every kernel here has a pure-jnp oracle in `ref.py`; pytest asserts
allclose between the two. Kernels run in Pallas interpret mode so the
lowered HLO contains plain ops executable by the CPU PJRT client (real
TPU lowering would emit a Mosaic custom-call — compile-only for us; see
DESIGN.md §Hardware-Adaptation).
"""

from .matmul import matmul
from .conv2d import conv2d, depthwise_conv2d
from .elementwise import bias_act, add_act
from .pool import maxpool2d, avgpool2d, global_avgpool

__all__ = [
    "matmul",
    "conv2d",
    "depthwise_conv2d",
    "bias_act",
    "add_act",
    "maxpool2d",
    "avgpool2d",
    "global_avgpool",
]
