"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: python/tests/ asserts
`assert_allclose(kernel(...), ref.<same>(...))` across shape/dtype
sweeps (hypothesis) before anything is AOT-lowered for the Rust side.
"""

import jax.numpy as jnp
from jax import lax


def matmul(x, w):
    return jnp.matmul(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _pad_tuple(padding):
    if padding in ("SAME", "VALID"):
        return padding
    return tuple(padding)


def conv2d(x, w, *, stride=1, padding="SAME"):
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=_pad_tuple(padding),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def depthwise_conv2d(x, w, *, stride=1, padding="SAME"):
    c = x.shape[-1]
    wf = w[:, :, None, :].astype(jnp.float32)  # (kh,kw,1,C) HWIO with groups=C
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        wf,
        window_strides=(stride, stride),
        padding=_pad_tuple(padding),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def bias_act(x, b, *, act="relu"):
    y = x.astype(jnp.float32) + b.astype(jnp.float32)
    return jnp.maximum(y, 0.0) if act == "relu" else y


def add_act(x, y, *, act="relu"):
    z = x.astype(jnp.float32) + y.astype(jnp.float32)
    return jnp.maximum(z, 0.0) if act == "relu" else z


def _pool(x, k, fn):
    n, h, w, c = x.shape
    x = x[:, : h - h % k, : w - w % k, :].astype(jnp.float32)
    n, h, w, c = x.shape
    return fn(x.reshape(n, h // k, k, w // k, k, c), axis=(2, 4))


def maxpool2d(x, *, k=2):
    return _pool(x, k, jnp.max)


def avgpool2d(x, *, k=2):
    return _pool(x, k, jnp.mean)


def global_avgpool(x):
    return jnp.mean(x.astype(jnp.float32), axis=(1, 2))
