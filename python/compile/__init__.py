"""Build-time-only package: L1 Pallas kernels, L2 JAX models, AOT lowering.

Nothing in here is imported at serving time — `make artifacts` runs
`compile.aot` once and the Rust coordinator consumes the emitted HLO
text + manifest.
"""
