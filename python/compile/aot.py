"""AOT lowering: JAX models -> HLO *text* artifacts + manifest for Rust.

HLO text (NOT `lowered.compile().serialize()` / proto bytes) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (behind the `xla` crate)
rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --outdir ../artifacts
Emits:  <outdir>/<model>_b<batch>.hlo.txt  for every (model, batch)
        <outdir>/manifest.json             shapes + SLOs for the runtime
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import BATCH_SIZES, CATALOG, build_model


def golden_input(shape) -> np.ndarray:
    """Deterministic, dtype-stable test input: ((i * 31) % 17) / 17."""
    n = int(np.prod(shape))
    flat = ((np.arange(n) * 31) % 17).astype(np.float32) / 17.0
    return flat.reshape(shape)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    `print_large_constants=True` is essential: the default elides big
    weight tensors as `constant({...})`, which the Rust-side HLO text
    parser silently reads as zeros.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def lower_model(name: str, batch: int):
    """Lower one (model, batch) to HLO text; returns (text, in/out shapes)."""
    apply_fn, example = build_model(name, batch)
    lowered = jax.jit(apply_fn).lower(example)
    out_shape = jax.eval_shape(apply_fn, example)
    return to_hlo_text(lowered), tuple(example.shape), tuple(out_shape.shape)


def artifact_name(name: str, batch: int) -> str:
    return f"{name}_b{batch}.hlo.txt"


def emit(outdir: str, models=None, batches=BATCH_SIZES, verbose=True) -> dict:
    """Lower every (model, batch) pair into `outdir`; write manifest.json."""
    os.makedirs(outdir, exist_ok=True)
    models = list(models or CATALOG)
    manifest = {"batch_sizes": list(batches), "models": {}}
    for name in models:
        info = CATALOG[name]
        entry = {
            "abbrev": info.abbrev,
            "slo_ms": info.slo_ms,
            "input_shape": list(info.input_shape),
            "artifacts": {},
        }
        for b in batches:
            text, in_shape, out_shape = lower_model(name, b)
            fname = artifact_name(name, b)
            with open(os.path.join(outdir, fname), "w") as f:
                f.write(text)
            entry["artifacts"][str(b)] = {
                "file": fname,
                "input_shape": list(in_shape),
                "output_shape": list(out_shape),
            }
            if verbose:
                print(f"  {fname}: in={in_shape} out={out_shape} ({len(text)} chars)")
        entry["output_dim"] = entry["artifacts"][str(batches[0])]["output_shape"][-1]
        # Golden vector: the L2 model's own output on a fixed input, so
        # the Rust runtime can verify end-to-end numerics (catches e.g.
        # constant elision or layout bugs in the interchange).
        b0 = batches[0]
        apply_fn, example = build_model(name, b0)
        gx = golden_input(example.shape)
        gy = np.asarray(apply_fn(jnp.asarray(gx)))
        entry["golden"] = {
            "batch": int(b0),
            "output": [round(float(v), 6) for v in gy[0].tolist()],
        }
        manifest["models"][name] = entry
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"wrote manifest for {len(models)} models to {outdir}/manifest.json")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=None)
    ap.add_argument(
        "--batches", nargs="*", type=int, default=list(BATCH_SIZES)
    )
    args = ap.parse_args()
    emit(args.outdir, models=args.models, batches=tuple(args.batches))


if __name__ == "__main__":
    main()
