"""Shared layer helpers for the L2 models — every layer is built on the
L1 Pallas kernels (matmul/conv2d/depthwise/bias_act/add_act/pools)."""

import jax
import jax.numpy as jnp

from ..kernels import (
    add_act,
    avgpool2d,
    bias_act,
    conv2d,
    depthwise_conv2d,
    global_avgpool,
    matmul,
    maxpool2d,
)

__all__ = [
    "ParamGen",
    "conv_relu",
    "dw_separable",
    "dense",
    "residual_block",
    "flatten",
    "add_act",
    "avgpool2d",
    "global_avgpool",
    "maxpool2d",
]


class ParamGen:
    """Deterministic parameter factory: He-style init from a seeded key.

    Serving never trains, so parameters only need stable, well-scaled
    values — the same seed yields bit-identical artifacts across builds
    (reproducible `make artifacts`).
    """

    def __init__(self, seed: int):
        self._key = jax.random.PRNGKey(seed)

    def _next(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def conv(self, kh, kw, cin, cout):
        fan_in = kh * kw * cin
        w = jax.random.normal(self._next(), (kh, kw, cin, cout), jnp.float32)
        return w * (2.0 / fan_in) ** 0.5

    def dwconv(self, kh, kw, c):
        w = jax.random.normal(self._next(), (kh, kw, c), jnp.float32)
        return w * (2.0 / (kh * kw)) ** 0.5

    def dense(self, din, dout):
        w = jax.random.normal(self._next(), (din, dout), jnp.float32)
        return w * (2.0 / din) ** 0.5

    def bias(self, d):
        return jnp.zeros((d,), jnp.float32)


def conv_relu(x, w, b, *, stride=1, padding="SAME", act="relu"):
    """conv2d -> fused bias+activation."""
    return bias_act(conv2d(x, w, stride=stride, padding=padding), b, act=act)


def dw_separable(x, dw_w, dw_b, pw_w, pw_b, *, stride=1):
    """MobileNet depthwise-separable block: dw conv -> relu -> 1x1 conv -> relu."""
    y = bias_act(depthwise_conv2d(x, dw_w, stride=stride), dw_b, act="relu")
    return bias_act(conv2d(y, pw_w, stride=1, padding="SAME"), pw_b, act="relu")


def dense(x, w, b, *, act="relu"):
    """matmul -> fused bias+activation."""
    return bias_act(matmul(x, w), b, act=act)


def residual_block(x, w1, b1, w2, b2, *, stride=1, proj_w=None, proj_b=None):
    """Two 3x3 convs with a (possibly projected) skip, post-add relu."""
    y = conv_relu(x, w1, b1, stride=stride)
    y = bias_act(conv2d(y, w2, stride=1, padding="SAME"), b2, act="none")
    skip = x
    if proj_w is not None:
        skip = bias_act(
            conv2d(x, proj_w, stride=stride, padding="SAME"), proj_b, act="none"
        )
    return add_act(y, skip, act="relu")


def flatten(x):
    return x.reshape(x.shape[0], -1)
