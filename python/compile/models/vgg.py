"""VGG-16 analogue (`vgg` in Table 4): the paper's heaviest model.

Three double-conv stages (VGG's defining stacked-3x3 pattern) on a
32x32x3 input plus the classifier MLP. Width is reduced vs the real
VGG-16 so the CPU PJRT client can serve it; the Rust latency model
carries the paper's true relative cost (Table 4 SLO = 130 ms).
"""

import jax.numpy as jnp

from . import common as C

INPUT_SHAPE = (32, 32, 3)
OUT_DIM = 10
SEED = 0x5667


def build(batch: int):
    g = C.ParamGen(SEED)
    widths = [(3, 24), (24, 48), (48, 96)]
    p = {}
    for i, (cin, cout) in enumerate(widths):
        p[f"s{i}_w1"] = g.conv(3, 3, cin, cout)
        p[f"s{i}_b1"] = g.bias(cout)
        p[f"s{i}_w2"] = g.conv(3, 3, cout, cout)
        p[f"s{i}_b2"] = g.bias(cout)
    p["f1_w"] = g.dense(4 * 4 * 96, 128)
    p["f1_b"] = g.bias(128)
    p["f2_w"] = g.dense(128, 64)
    p["f2_b"] = g.bias(64)
    p["f3_w"] = g.dense(64, OUT_DIM)
    p["f3_b"] = g.bias(OUT_DIM)

    def apply(x):
        y = x
        for i in range(len(widths)):
            y = C.conv_relu(y, p[f"s{i}_w1"], p[f"s{i}_b1"])
            y = C.conv_relu(y, p[f"s{i}_w2"], p[f"s{i}_b2"])
            y = C.maxpool2d(y, k=2)
        y = C.flatten(y)
        y = C.dense(y, p["f1_w"], p["f1_b"])
        y = C.dense(y, p["f2_w"], p["f2_b"])
        return C.dense(y, p["f3_w"], p["f3_b"], act="none")

    example = jnp.zeros((batch,) + INPUT_SHAPE, jnp.float32)
    return apply, example
