"""SSD-MobileNet-V1 analogue (`ssd` in Table 4): depthwise-separable
backbone + SSD detection heads.

Input mirrors the paper's 300x300 camera frames at reduced resolution
(38x38, the size of SSD300's first feature map). The backbone is four
depthwise-separable blocks; two sibling 3x3 conv heads emit per-anchor
class scores and box regressions, flattened and concatenated into one
(batch, dets) tensor so every model presents a single output to the
runtime (SLO carried in Rust: 136 ms).
"""

import jax.numpy as jnp

from . import common as C

INPUT_SHAPE = (38, 38, 3)
NUM_ANCHORS = 4
NUM_CLASSES = 6
SEED = 0x55D


def build(batch: int):
    g = C.ParamGen(SEED)
    p = {"stem_w": g.conv(3, 3, 3, 16), "stem_b": g.bias(16)}
    blocks = [(16, 24, 1), (24, 32, 2), (32, 48, 1), (48, 64, 2)]
    for i, (cin, cout, _s) in enumerate(blocks):
        p[f"b{i}_dw_w"] = g.dwconv(3, 3, cin)
        p[f"b{i}_dw_b"] = g.bias(cin)
        p[f"b{i}_pw_w"] = g.conv(1, 1, cin, cout)
        p[f"b{i}_pw_b"] = g.bias(cout)
    p["cls_w"] = g.conv(3, 3, 64, NUM_ANCHORS * NUM_CLASSES)
    p["cls_b"] = g.bias(NUM_ANCHORS * NUM_CLASSES)
    p["loc_w"] = g.conv(3, 3, 64, NUM_ANCHORS * 4)
    p["loc_b"] = g.bias(NUM_ANCHORS * 4)

    def apply(x):
        y = C.conv_relu(x, p["stem_w"], p["stem_b"])
        for i, (_cin, _cout, s) in enumerate(blocks):
            y = C.dw_separable(
                y,
                p[f"b{i}_dw_w"], p[f"b{i}_dw_b"],
                p[f"b{i}_pw_w"], p[f"b{i}_pw_b"],
                stride=s,
            )
        cls = C.conv_relu(y, p["cls_w"], p["cls_b"], act="none")
        loc = C.conv_relu(y, p["loc_w"], p["loc_b"], act="none")
        return jnp.concatenate([C.flatten(cls), C.flatten(loc)], axis=-1)

    example = jnp.zeros((batch,) + INPUT_SHAPE, jnp.float32)
    return apply, example
