"""Layer-2 served models — tiny JAX analogues of the paper's five DNNs.

Each module exposes `build(batch) -> (apply_fn, example_input)` where
`apply_fn` closes over deterministic (seeded) parameters and returns a
single (batch, out_dim) logits/detections tensor. All hot FLOPs flow
through the L1 Pallas kernels.

The paper served PyTorch GoogLeNet / LeNet / ResNet50 / SSD-MobileNet /
VGG-16 on 2080 Ti GPUs. Our CPU-PJRT substrate cannot run those at
serving rates, so we keep the topology *family* (inception branches,
residual skips, depthwise-separable + detection heads, deep VGG stacks)
at reduced width/depth, and carry the paper's relative cost ratios in
the Rust latency model (DESIGN.md §3 substitution table).
"""

from . import lenet, googlenet, resnet, ssd_mobilenet, vgg

BUILDERS = {
    "lenet": lenet.build,
    "googlenet": googlenet.build,
    "resnet": resnet.build,
    "ssd_mobilenet": ssd_mobilenet.build,
    "vgg": vgg.build,
}

__all__ = ["BUILDERS", "lenet", "googlenet", "resnet", "ssd_mobilenet", "vgg"]
