"""ResNet-50 analogue (`res` in Table 4): residual skip topology.

Stem conv + three residual stages (16 -> 32 -> 64 channels, stride-2
downsampling with projected skips) + global average pool + classifier —
the ResNet pattern at CPU-serveable width (SLO carried in Rust: 95 ms).
"""

import jax.numpy as jnp

from . import common as C

INPUT_SHAPE = (32, 32, 3)
OUT_DIM = 10
SEED = 0x50


def build(batch: int):
    g = C.ParamGen(SEED)
    p = {"stem_w": g.conv(3, 3, 3, 16), "stem_b": g.bias(16)}
    stages = [(16, 16, 1), (16, 32, 2), (32, 64, 2)]
    for i, (cin, cout, stride) in enumerate(stages):
        p[f"r{i}_w1"] = g.conv(3, 3, cin, cout)
        p[f"r{i}_b1"] = g.bias(cout)
        p[f"r{i}_w2"] = g.conv(3, 3, cout, cout)
        p[f"r{i}_b2"] = g.bias(cout)
        if cin != cout or stride != 1:
            p[f"r{i}_pw"] = g.conv(1, 1, cin, cout)
            p[f"r{i}_pb"] = g.bias(cout)
    p["fc_w"] = g.dense(64, OUT_DIM)
    p["fc_b"] = g.bias(OUT_DIM)

    def apply(x):
        y = C.conv_relu(x, p["stem_w"], p["stem_b"])
        for i, (cin, cout, stride) in enumerate(stages):
            proj_w = p.get(f"r{i}_pw")
            proj_b = p.get(f"r{i}_pb")
            y = C.residual_block(
                y,
                p[f"r{i}_w1"], p[f"r{i}_b1"],
                p[f"r{i}_w2"], p[f"r{i}_b2"],
                stride=stride, proj_w=proj_w, proj_b=proj_b,
            )
        y = C.global_avgpool(y)
        return C.dense(y, p["fc_w"], p["fc_b"], act="none")

    example = jnp.zeros((batch,) + INPUT_SHAPE, jnp.float32)
    return apply, example
