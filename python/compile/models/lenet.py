"""LeNet-5 (`le` in Table 4): MNIST 1x28x28, the paper's short-latency model.

Kept at full original size — LeNet is already tiny.
"""

import jax.numpy as jnp

from . import common as C

INPUT_SHAPE = (28, 28, 1)  # HWC
OUT_DIM = 10
SEED = 0x1E


def build(batch: int):
    g = C.ParamGen(SEED)
    p = {
        "c1_w": g.conv(5, 5, 1, 6), "c1_b": g.bias(6),
        "c2_w": g.conv(5, 5, 6, 16), "c2_b": g.bias(16),
        "f1_w": g.dense(7 * 7 * 16, 120), "f1_b": g.bias(120),
        "f2_w": g.dense(120, 84), "f2_b": g.bias(84),
        "f3_w": g.dense(84, OUT_DIM), "f3_b": g.bias(OUT_DIM),
    }

    def apply(x):
        y = C.conv_relu(x, p["c1_w"], p["c1_b"])
        y = C.maxpool2d(y, k=2)
        y = C.conv_relu(y, p["c2_w"], p["c2_b"])
        y = C.maxpool2d(y, k=2)
        y = C.flatten(y)
        y = C.dense(y, p["f1_w"], p["f1_b"])
        y = C.dense(y, p["f2_w"], p["f2_b"])
        return C.dense(y, p["f3_w"], p["f3_b"], act="none")

    example = jnp.zeros((batch,) + INPUT_SHAPE, jnp.float32)
    return apply, example
