"""GoogLeNet analogue (`goo` in Table 4): inception parallel-branch topology.

Stem conv + two inception blocks (1x1 / 3x3 / 5x5 / pool-proj branches,
channel-concatenated) + global average pool + classifier (SLO carried
in Rust: 44 ms).
"""

import jax.numpy as jnp

from . import common as C

INPUT_SHAPE = (32, 32, 3)
OUT_DIM = 10
SEED = 0x600


def _inception_params(g, name, cin, b1, b3r, b3, b5r, b5, bp):
    return {
        f"{name}_1_w": g.conv(1, 1, cin, b1), f"{name}_1_b": g.bias(b1),
        f"{name}_3r_w": g.conv(1, 1, cin, b3r), f"{name}_3r_b": g.bias(b3r),
        f"{name}_3_w": g.conv(3, 3, b3r, b3), f"{name}_3_b": g.bias(b3),
        f"{name}_5r_w": g.conv(1, 1, cin, b5r), f"{name}_5r_b": g.bias(b5r),
        f"{name}_5_w": g.conv(5, 5, b5r, b5), f"{name}_5_b": g.bias(b5),
        f"{name}_p_w": g.conv(1, 1, cin, bp), f"{name}_p_b": g.bias(bp),
    }


def _inception(x, p, name):
    import jax.numpy as jnp

    b1 = C.conv_relu(x, p[f"{name}_1_w"], p[f"{name}_1_b"])
    b3 = C.conv_relu(x, p[f"{name}_3r_w"], p[f"{name}_3r_b"])
    b3 = C.conv_relu(b3, p[f"{name}_3_w"], p[f"{name}_3_b"])
    b5 = C.conv_relu(x, p[f"{name}_5r_w"], p[f"{name}_5r_b"])
    b5 = C.conv_relu(b5, p[f"{name}_5_w"], p[f"{name}_5_b"])
    # Pool branch: 2x2 avg-pool has stride k in our kernel set; inception
    # wants stride-1 SAME pooling, so approximate with a 1x1 projection
    # of the input (standard in reduced inception variants).
    bp = C.conv_relu(x, p[f"{name}_p_w"], p[f"{name}_p_b"])
    return jnp.concatenate([b1, b3, b5, bp], axis=-1)


def build(batch: int):
    g = C.ParamGen(SEED)
    p = {"stem_w": g.conv(3, 3, 3, 16), "stem_b": g.bias(16)}
    # in=16 -> out 8+16+8+8 = 40; in=40 -> out 16+24+12+12 = 64
    p.update(_inception_params(g, "inc0", 16, 8, 8, 16, 4, 8, 8))
    p.update(_inception_params(g, "inc1", 40, 16, 12, 24, 6, 12, 12))
    p["fc_w"] = g.dense(64, OUT_DIM)
    p["fc_b"] = g.bias(OUT_DIM)

    def apply(x):
        y = C.conv_relu(x, p["stem_w"], p["stem_b"])
        y = C.maxpool2d(y, k=2)
        y = _inception(y, p, "inc0")
        y = _inception(y, p, "inc1")
        y = C.global_avgpool(y)
        return C.dense(y, p["fc_w"], p["fc_b"], act="none")

    example = jnp.zeros((batch,) + INPUT_SHAPE, jnp.float32)
    return apply, example
