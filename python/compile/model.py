"""L2 model registry — the single entry point the AOT pipeline and tests use.

Catalog metadata mirrors the paper's Table 4 (model set, input data,
SLO). SLOs are enforced by the Rust coordinator, not here; they ride
along in the artifact manifest so the serving side needs no Python.
"""

from dataclasses import dataclass

from .models import BUILDERS
from .models import googlenet, lenet, resnet, ssd_mobilenet, vgg

#: Batch sizes the paper sweeps (Fig 3) and the max it serves (Table 4).
BATCH_SIZES = (1, 2, 4, 8, 16, 32)


@dataclass(frozen=True)
class ModelInfo:
    """Static, serving-relevant facts about one served model."""

    name: str
    abbrev: str
    input_shape: tuple  # HWC, per-sample
    out_dim_hint: str
    slo_ms: float  # Table 4 SLO (2x solo latency at b=32 on the paper GPU)


CATALOG = {
    "lenet": ModelInfo("lenet", "le", lenet.INPUT_SHAPE, "10 logits", 5.0),
    "googlenet": ModelInfo("googlenet", "goo", googlenet.INPUT_SHAPE, "10 logits", 44.0),
    "resnet": ModelInfo("resnet", "res", resnet.INPUT_SHAPE, "10 logits", 95.0),
    "ssd_mobilenet": ModelInfo(
        "ssd_mobilenet", "ssd", ssd_mobilenet.INPUT_SHAPE, "cls+loc dets", 136.0
    ),
    "vgg": ModelInfo("vgg", "vgg", vgg.INPUT_SHAPE, "10 logits", 130.0),
}

MODEL_NAMES = tuple(CATALOG)


def build_model(name: str, batch: int):
    """Return `(apply_fn, example_input)` for `name` at `batch`."""
    if name not in BUILDERS:
        raise KeyError(f"unknown model {name!r}; have {sorted(BUILDERS)}")
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    return BUILDERS[name](batch)
